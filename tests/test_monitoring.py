"""Continuous-monitoring tests (ISSUE 9): the time-series sampler and its
reset-safe windowed deltas, multi-window burn-rate SLO alerting with
hysteresis, per-kernel profiling histograms, JSON logging parity, the
bench-history diff, and the live serving e2e — server under client load,
injected reader kill, merged scrape matching per-reader stats, exactly one
de-flapped SLO alert.
"""
import json
import math
import threading
import time
import types

import pytest

from repro.obs.metrics import MetricsRegistry
from repro.obs.slo import SLOEvaluator, SLOSpec, default_serving_slos
from repro.obs.timeseries import (TimeSeriesSampler, merge_hist_states,
                                  reset_safe_delta)


class Clock:
    """Manual monotonic clock for deterministic sampler tests."""

    def __init__(self, t: float = 0.0):
        self.t = t

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> None:
        self.t += dt


def _sampler_over(reg: MetricsRegistry, clock: Clock,
                  **kw) -> TimeSeriesSampler:
    return TimeSeriesSampler(source=reg, clock=clock, **kw)


class TestTimeSeriesSampler:
    def test_windowed_rate_and_percentile_exact(self):
        reg = MetricsRegistry()
        clock = Clock()
        s = _sampler_over(reg, clock)
        s.sample_now()
        c = reg.counter("serve.requests")
        h = reg.histogram("serve.latency_seconds")
        for ms in range(1, 101):
            c.inc()
            h.observe(ms / 1e3)
        clock.advance(10.0)
        s.sample_now()
        assert s.rate("serve.requests", 30.0) == pytest.approx(10.0)
        assert s.percentile("serve.latency_seconds", 50,
                            30.0) == pytest.approx(0.050)
        assert s.percentile("serve.latency_seconds", 99,
                            30.0) == pytest.approx(0.099)

    def test_window_selects_trailing_seconds(self):
        reg = MetricsRegistry()
        clock = Clock()
        s = _sampler_over(reg, clock)
        c = reg.counter("x")
        s.sample_now()                      # t=0
        for _ in range(12):                 # samples every 10s up to t=120
            if clock.t < 50:
                c.inc(10)                   # burst: 50 events before t=50
            clock.advance(10.0)
            s.sample_now()
        # a 30s window is past the burst entirely: zero rate
        assert s.rate("x", 30.0) == 0.0
        # the full window sees everything
        assert s.rate("x", 1000.0) == pytest.approx(50.0 / 120.0)

    def test_empty_window_is_none_and_nan(self):
        reg = MetricsRegistry()
        s = _sampler_over(reg, Clock())
        assert s.window(30.0) is None
        assert math.isnan(s.rate("x", 30.0))
        assert math.isnan(s.percentile("x", 99, 30.0))
        s.sample_now()                      # one sample: still no delta
        assert s.window(30.0) is None

    def test_counter_reset_never_negative(self):
        # a respawned reader restarts its counters: the merged snapshot
        # dips from 10 to 3 — the delta must clamp to zero, not go to -7
        before = {"counters": {"serve.requests": 10.0}, "gauges": {},
                  "histograms": {}}
        after = {"counters": {"serve.requests": 3.0}, "gauges": {},
                 "histograms": {}}
        d = reset_safe_delta(before, after)
        assert d["counters"].get("serve.requests", 0.0) == 0.0
        # and through the sampler: the windowed rate is 0, never negative
        snaps = iter([before, after])
        clock = Clock()
        s = TimeSeriesSampler(source=lambda: next(snaps), clock=clock)
        s.sample_now()
        clock.advance(5.0)
        s.sample_now()
        assert s.rate("serve.requests", 30.0) == 0.0

    def test_histogram_reset_clamped_per_bucket(self):
        big, small = MetricsRegistry(), MetricsRegistry()
        for ms in (1, 2, 3, 4, 5):
            big.histogram("lat").observe(ms / 1e3)
        for ms in (1, 2):
            small.histogram("lat").observe(ms / 1e3)
        d = reset_safe_delta(big.snapshot(), small.snapshot())
        st = d["histograms"].get("lat")
        # every bucket went backwards -> all clamp to zero -> dropped
        assert st is None
        # partial reset: one reader restarted, another kept going
        merged = MetricsRegistry()
        merged.merge(small.snapshot())
        for ms in (50, 60):                 # survivor's new samples
            merged.histogram("lat").observe(ms / 1e3)
        d = reset_safe_delta(big.snapshot(), merged.snapshot())
        st = d["histograms"]["lat"]
        assert st["count"] == 2
        assert all(c >= 0 for c in st["counts"])

    def test_merge_hist_states_exact(self):
        a, b = MetricsRegistry(), MetricsRegistry()
        for v in (0.01, 0.02):
            a.histogram("h").observe(v)
        for v in (0.03, 0.04):
            b.histogram("h").observe(v)
        st = merge_hist_states([a.snapshot()["histograms"]["h"],
                                b.snapshot()["histograms"]["h"]])
        assert st["count"] == 4
        assert st["total"] == pytest.approx(0.10)
        assert st["min"] == pytest.approx(0.01)
        assert st["max"] == pytest.approx(0.04)

    def test_ring_is_bounded(self):
        reg = MetricsRegistry()
        clock = Clock()
        s = _sampler_over(reg, clock, capacity=3)
        for _ in range(10):
            clock.advance(1.0)
            s.sample_now()
        assert len(s) == 3

    def test_thread_shutdown_leaves_nothing_dangling(self):
        reg = MetricsRegistry()
        s = TimeSeriesSampler(source=reg, interval_s=0.01)
        s.start()
        assert s.running
        deadline = time.monotonic() + 5.0
        while len(s) < 2 and time.monotonic() < deadline:
            time.sleep(0.01)
        assert len(s) >= 2, "sampler thread never sampled"
        s.stop()
        assert not s.running
        assert not any(t.name == "obs-sampler" and t.is_alive()
                       for t in threading.enumerate()), (
            "sampler thread still alive after stop()")
        s.stop()                            # idempotent

    def test_sampler_survives_broken_source(self):
        calls = []

        def flaky():
            calls.append(1)
            if len(calls) == 1:
                raise OSError("scrape failed")
            return {"counters": {}, "gauges": {}, "histograms": {}}

        s = TimeSeriesSampler(source=flaky, interval_s=0.01)
        s.start()
        deadline = time.monotonic() + 5.0
        while len(s) < 1 and time.monotonic() < deadline:
            time.sleep(0.01)
        s.stop()
        assert len(s) >= 1, "one bad scrape killed the sampler"


class TestSLOEvaluator:
    def _latency_setup(self, threshold=0.1):
        reg = MetricsRegistry()
        clock = Clock()
        sampler = _sampler_over(reg, clock)
        spec = SLOSpec("p99", "latency_p", "lat", threshold, p=99.0,
                       fast_window_s=10.0, slow_window_s=20.0)
        ev = SLOEvaluator([spec], sampler, registry=reg)
        return reg, clock, sampler, ev

    def test_fires_once_and_resolves_once(self):
        reg, clock, sampler, ev = self._latency_setup()
        sampler.sample_now()
        for _ in range(20):
            reg.histogram("lat").observe(0.5)       # way over 0.1s
        clock.advance(5.0)
        sampler.sample_now()
        ev.evaluate(now=clock())
        assert ev.firing() == ["p99"]
        assert len(ev.alerts) == 1 and ev.alerts[0]["state"] == "firing"
        # more bad data, more evaluations: NO additional alert
        for _ in range(3):
            reg.histogram("lat").observe(0.5)
            clock.advance(2.0)
            sampler.sample_now()
            ev.evaluate(now=clock())
        assert len(ev.alerts) == 1
        # age the bad samples out of both windows, then serve good traffic
        clock.advance(30.0)
        sampler.sample_now()
        for _ in range(20):
            reg.histogram("lat").observe(0.01)
        clock.advance(5.0)
        sampler.sample_now()
        ev.evaluate(now=clock())
        assert ev.firing() == []
        assert len(ev.alerts) == 2 and ev.alerts[1]["state"] == "ok"
        assert [st.state for st in ev.statuses] == ["ok"]

    def test_hysteresis_band_does_not_flap(self):
        # after firing, values inside (threshold*clear_ratio, threshold]
        # are neither a violation nor a clear: state holds, no transitions
        reg, clock, sampler, ev = self._latency_setup(threshold=0.1)
        sampler.sample_now()
        for _ in range(20):
            reg.histogram("lat").observe(0.5)
        clock.advance(5.0)
        sampler.sample_now()
        ev.evaluate(now=clock())
        assert len(ev.alerts) == 1
        for _ in range(5):                  # hover in the hysteresis band
            clock.advance(30.0)             # old samples age out each round
            sampler.sample_now()
            for _ in range(20):
                reg.histogram("lat").observe(0.095)     # 0.09 < v <= 0.1
            clock.advance(5.0)
            sampler.sample_now()
            ev.evaluate(now=clock())
        assert ev.firing() == ["p99"], "hysteresis band cleared the alert"
        assert len(ev.alerts) == 1, "alert flapped inside the band"

    def test_fast_window_alone_does_not_fire(self):
        # a blip that violates only the fast window must not page
        reg = MetricsRegistry()
        clock = Clock()
        sampler = _sampler_over(reg, clock)
        spec = SLOSpec("p99", "latency_p", "lat", 0.1, p=50.0,
                       fast_window_s=10.0, slow_window_s=60.0)
        ev = SLOEvaluator([spec], sampler)
        sampler.sample_now()
        for _ in range(100):
            reg.histogram("lat").observe(0.01)      # long good history
        clock.advance(50.0)
        sampler.sample_now()                        # t=50
        for _ in range(3):
            reg.histogram("lat").observe(0.5)       # short blip after t=50
        clock.advance(10.0)
        sampler.sample_now()                        # t=60
        ev.evaluate(now=clock())
        # fast window (50..60) is all blip and violates; slow window
        # (0..60) p50 is still good — multi-window must NOT fire
        assert ev.firing() == []
        assert not ev.alerts

    def test_events_kind_counts_window_delta(self):
        reg = MetricsRegistry()
        clock = Clock()
        sampler = _sampler_over(reg, clock)
        spec = SLOSpec("respawns", "events", "serve.reader_respawns", 0.0,
                       fast_window_s=5.0, slow_window_s=10.0)
        ev = SLOEvaluator([spec], sampler, registry=reg)
        sampler.sample_now()
        clock.advance(1.0)
        sampler.sample_now()
        ev.evaluate(now=clock())
        assert ev.firing() == []
        reg.counter("serve.reader_respawns", reader="0").inc()
        clock.advance(1.0)
        sampler.sample_now()
        ev.evaluate(now=clock())
        assert ev.firing() == ["respawns"]
        firing_alerts = [a for a in ev.alerts if a["state"] == "firing"]
        assert len(firing_alerts) == 1
        # once the respawn leaves both windows the spec clears (0 <= 0*0.9)
        clock.advance(15.0)
        sampler.sample_now()
        clock.advance(1.0)
        sampler.sample_now()
        ev.evaluate(now=clock())
        assert ev.firing() == []
        assert [a["state"] for a in ev.alerts] == ["firing", "ok"]
        snap = reg.snapshot()["counters"]
        assert snap.get(
            "slo.transitions{slo=respawns,state=firing}") == 1.0

    def test_no_data_neither_fires_nor_clears(self):
        reg = MetricsRegistry()
        sampler = _sampler_over(reg, Clock())
        ev = SLOEvaluator(default_serving_slos(), sampler)
        statuses = ev.evaluate()
        assert {st.state for st in statuses} == {"no_data"}
        assert not ev.alerts and ev.firing() == []
        # to_dict maps NaN to None (JSON-safe for the scrape reply)
        d = statuses[0].to_dict()
        assert d["value_fast"] is None and d["value_slow"] is None
        json.dumps([st.to_dict() for st in statuses])

    def test_ratio_with_zero_denominator(self):
        reg = MetricsRegistry()
        clock = Clock()
        sampler = _sampler_over(reg, clock)
        spec = SLOSpec("errs", "ratio", "serve.errors", 0.5,
                       denominator="serve.requests",
                       fast_window_s=5.0, slow_window_s=10.0)
        ev = SLOEvaluator([spec], sampler)
        sampler.sample_now()
        reg.counter("serve.errors").inc(3)          # errors, zero requests
        clock.advance(1.0)
        sampler.sample_now()
        ev.evaluate(now=clock())
        assert ev.firing() == ["errs"], "errors without requests must fire"

    def test_bad_spec_rejected(self):
        with pytest.raises(ValueError):
            SLOSpec("x", "bogus_kind", "k", 1.0)
        with pytest.raises(ValueError):
            SLOSpec("x", "ratio", "k", 1.0)          # no denominator


class TestKernelProfiling:
    def test_profile_kernels_fills_tuned_and_default_histograms(self):
        from repro.kernels.profile import (KERNELS, default_workloads,
                                           profile_kernels)
        reg = MetricsRegistry()
        res = profile_kernels(device="tpu_v5e",
                              workloads=default_workloads(seq=32, width=32,
                                                          head_dim=16),
                              metrics_registry=reg, interpret=True)
        assert set(res) == set(KERNELS)
        hists = reg.snapshot()["histograms"]
        for kernel in KERNELS:
            for source in ("default", "tuned"):
                key = (f"kernel.seconds{{config={source},"
                       f"device=tpu_v5e,kernel={kernel}}}")
                assert key in hists, sorted(hists)
                assert hists[key]["count"] >= 1
            assert res[kernel]["tuned"] > 0 and res[kernel]["default"] > 0

    def test_ops_dispatch_profiling_opt_in(self, monkeypatch):
        import jax.numpy as jnp

        from repro.kernels import ops
        from repro.obs import metrics as obs_metrics
        monkeypatch.delenv("REPRO_KERNEL_PROFILE", raising=False)
        ops.reset_profiling()
        reg = MetricsRegistry()
        obs_metrics.push_registry(reg)
        try:
            a = jnp.ones((32, 32), jnp.float32)
            ops.tuned_matmul(a, a, interpret=True)  # profiling off: silent
            assert not reg.snapshot()["histograms"]
            ops.enable_profiling()
            ops.tuned_matmul(a, a, interpret=True)
        finally:
            ops.reset_profiling()
            obs_metrics.pop_registry(reg)
        hists = reg.snapshot()["histograms"]
        key = "kernel.seconds{config=tuned,device=tpu_v5e,kernel=matmul}"
        assert key in hists and hists[key]["count"] == 1


class TestEngineProfiling:
    def test_decode_run_leaves_per_kernel_histograms(self):
        """Acceptance: one serve/engine decode run with profiling on leaves
        timing histograms for all three kernels plus engine-level timing."""
        import jax
        import numpy as np

        from repro.configs import get_smoke_config
        from repro.models import build_model
        from repro.obs import metrics as obs_metrics
        from repro.serve import Engine, Request

        cfg = get_smoke_config("xlstm-350m")
        model = build_model(cfg)
        try:
            mesh = jax.make_mesh((1, 1), ("data", "model"),
                                 axis_types=(jax.sharding.AxisType.Auto,) * 2)
        except (AttributeError, TypeError):  # older jax: no axis_types
            mesh = jax.make_mesh((1, 1), ("data", "model"))
        params = model.init(jax.random.PRNGKey(0))
        reg = MetricsRegistry()
        obs_metrics.push_registry(reg)
        try:
            eng = Engine(model, params, mesh, max_len=32, batch_slots=2,
                         profile_kernels=True)
            prompt = np.arange(1, 9, dtype=np.int32) % cfg.vocab_size
            eng.generate([Request(prompt=prompt, max_new_tokens=4)])
        finally:
            obs_metrics.pop_registry(reg)
        hists = reg.snapshot()["histograms"]
        for kernel in ("matmul", "attention", "scan"):
            keys = [k for k in hists
                    if k.startswith("kernel.seconds")
                    and f"kernel={kernel}" in k]
            assert keys, (kernel, sorted(hists))
        assert hists["serve.engine.prefill_seconds"]["count"] == 1
        assert hists["serve.engine.step_seconds"]["count"] == 3
        assert reg.snapshot()["counters"]["serve.engine.tokens"] == 4.0


class TestJsonLogging:
    FIELDS = {"device": "tpu_v5e", "n": 3, "ratio": 0.5, "flag": True,
              "obj": ["not", "scalar"]}

    def test_json_lines_carry_the_same_fields_as_human(self, capsys,
                                                       monkeypatch):
        from repro.obs.logging import get_logger
        monkeypatch.setenv("REPRO_LOG_LEVEL", "info")
        log = get_logger("jsontest")

        monkeypatch.setenv("REPRO_LOG_JSON", "1")
        log.info("drift check", **self.FIELDS)
        json_line = capsys.readouterr().err.strip()
        rec = json.loads(json_line)
        assert rec["level"] == "info" and rec["logger"] == "jsontest"
        assert rec["msg"] == "drift check"
        assert rec["device"] == "tpu_v5e" and rec["n"] == 3
        assert rec["ratio"] == 0.5 and rec["flag"] is True
        assert rec["obj"] == "['not', 'scalar']"     # non-scalar stringified
        assert isinstance(rec["t"], float)

        monkeypatch.delenv("REPRO_LOG_JSON")
        log.info("drift check", **self.FIELDS)
        human = capsys.readouterr().err.strip()
        assert human.startswith("[jsontest] drift check")
        for k in self.FIELDS:                        # identical field set
            assert f"{k}=" in human

    def test_json_respects_level_threshold(self, capsys, monkeypatch):
        from repro.obs.logging import get_logger
        monkeypatch.setenv("REPRO_LOG_LEVEL", "warning")
        monkeypatch.setenv("REPRO_LOG_JSON", "1")
        log = get_logger("jsontest2")
        log.info("suppressed")
        assert capsys.readouterr().err == ""
        log.warning("kept", x=1)
        rec = json.loads(capsys.readouterr().err.strip())
        assert rec["level"] == "warning" and rec["x"] == 1


class TestBenchHistory:
    def _write(self, monkeypatch, tmp_path, suite, metrics):
        import benchmarks.run as bench_run
        monkeypatch.setattr(bench_run, "REPO_ROOT", str(tmp_path))
        bench_run.write_bench_json(suite, metrics)

    def test_history_appends_one_line_per_run(self, monkeypatch, tmp_path,
                                              capsys):
        self._write(monkeypatch, tmp_path, "hub", {"qps": 100.0})
        self._write(monkeypatch, tmp_path, "hub", {"qps": 120.0})
        capsys.readouterr()
        hist = tmp_path / "artifacts" / "bench_history.jsonl"
        rows = [json.loads(ln) for ln in
                hist.read_text().strip().splitlines()]
        assert len(rows) == 2
        assert all(r["suite"] == "hub" and "recorded_at" in r for r in rows)
        assert rows[1]["metrics"] == [{"metric": "qps", "value": 120.0}]

    def test_diff_flags_regressions_by_direction(self, monkeypatch,
                                                 tmp_path, capsys):
        from repro.launch.obs import diff_bench_history
        good = {"qps": 100.0, "hit_p99_ms": 10.0}
        bad = {"qps": 50.0, "hit_p99_ms": 20.0}      # both directions worse
        self._write(monkeypatch, tmp_path, "hub", good)
        self._write(monkeypatch, tmp_path, "hub", bad)
        capsys.readouterr()
        hist = str(tmp_path / "artifacts" / "bench_history.jsonl")
        assert diff_bench_history(hist) == 1
        out = capsys.readouterr().out
        assert out.count("REGRESSION") == 2

        # improvement (or noise inside tolerance) passes
        self._write(monkeypatch, tmp_path, "hub", good)
        capsys.readouterr()
        assert diff_bench_history(hist) == 0
        # single entry for a fresh suite: nothing to diff, not a failure
        self._write(monkeypatch, tmp_path, "sched", {"x": 1.0})
        capsys.readouterr()
        assert diff_bench_history(hist, suite="sched") == 0

    def test_diff_missing_history_fails(self, tmp_path, capsys):
        from repro.launch.obs import diff_bench_history
        assert diff_bench_history(str(tmp_path / "none.jsonl")) == 1
        capsys.readouterr()


# --- live monitoring end to end (the acceptance e2e) -----------------------


class TestServingMonitoringE2E:
    def test_scrape_health_kill_and_single_alert(self, tmp_path, capsys):
        """Server under client load + injected reader kill: the merged
        scrape exposition's p50/p99 match the loaded reader's own stats,
        the health payload shows the respawn, exactly one de-flapped SLO
        alert fires, and the --watch --once --check gate flips 0 -> 1 ->
        0 around the violation."""
        from repro.autotune.registry import Registry
        from repro.autotune.space import (ProgramConfig, Workload,
                                          default_config)
        from repro.hub.serving.client import HubClient
        from repro.hub.serving.server import HubServer
        from repro.hub.store import RecordStore
        from repro.launch import obs as obs_cli
        from repro.obs.metrics import hist_percentile
        from repro.obs.timeseries import merge_hist_states

        wl = Workload("matmul", (256, 256, 128), name="a")
        cfg = default_config(wl)
        root = str(tmp_path / "hub")
        store = RecordStore(root + "/store")
        store.put("tpu_v5e", wl,
                  ProgramConfig.make(block_m=64, block_n=128, block_k=128,
                                     k_inner=0, unroll=1, out_bf16=1),
                  50.0)
        store.flush()
        reg = Registry(path=root + "/tuned_configs.json")
        reg.put("tpu_v5e", wl, cfg, 100.0)
        shim = types.SimpleNamespace(store=store, registry=reg)
        specs = [SLOSpec("reader-respawns", "events",
                         "serve.reader_respawns", 0.0,
                         fast_window_s=2.0, slow_window_s=4.0)]

        with HubServer(root, hub=shim, readers=2, tune_on_miss=False,
                       heartbeat_s=0.05, hb_grace_s=0.5,
                       monitor_interval_s=0.1, slos=specs) as srv:
            # client load against reader index 1 only, so killing reader 0
            # later cannot lose the latency samples we compare against
            eps = srv.endpoints()
            with HubClient(root=root, endpoints=[eps[1]]) as c:
                for _ in range(40):
                    r = c.get_config("tpu_v5e", wl, tune=False)
                    assert r.source in ("registry", "cache")
                loaded_stats = c.stats()

            # wait for a post-load scrape so the merged view is current
            deadline = time.monotonic() + 10
            while time.monotonic() < deadline:
                snap = srv.sampler.latest()
                if snap and any(
                        k.startswith("serve.latency_seconds")
                        for k in snap.get("histograms", {})):
                    break
                time.sleep(0.05)

            metrics_reply = obs_cli._writer_call(root, "metrics")
            health = obs_cli._writer_call(root, "health")
            assert metrics_reply["ok"] and health["ok"]
            assert health["alive"] == 2 and health["respawns"] == 0
            assert "serve.latency_seconds" in metrics_reply["text"]

            # merged scrape percentiles == the loaded reader's own stats
            # (the idle reader contributes empty histograms)
            states = [st for k, st in
                      metrics_reply["snapshot"]["histograms"].items()
                      if k.startswith("serve.latency_seconds")]
            merged = merge_hist_states(states)
            assert merged["count"] == loaded_stats["hit"]["n"]
            assert hist_percentile(merged, 50) * 1e3 == pytest.approx(
                loaded_stats["hit"]["p50_ms"])
            assert hist_percentile(merged, 99) * 1e3 == pytest.approx(
                loaded_stats["hit"]["p99_ms"])

            # gate passes while healthy
            rc = obs_cli.main(["--watch", "--once", "--check",
                               "--root", root])
            capsys.readouterr()
            assert rc == 0

            # inject the reader kill
            victim = srv._readers[0]
            victim.proc.kill()
            deadline = time.monotonic() + 30
            while srv.respawns < 1 and time.monotonic() < deadline:
                time.sleep(0.05)
            assert srv.respawns == 1, "watchdog never respawned the reader"

            health = obs_cli._writer_call(root, "health")
            assert health["respawns"] == 1
            assert health["respawns_by_reader"] == {"0": 1}

            # exactly ONE firing alert, held across many monitor ticks
            deadline = time.monotonic() + 15
            while not srv.slo.firing() and time.monotonic() < deadline:
                time.sleep(0.05)
            assert srv.slo.firing() == ["reader-respawns"]
            time.sleep(0.5)                 # several more evaluations
            firing_alerts = [a for a in srv.slo.alerts
                             if a["state"] == "firing"]
            assert len(firing_alerts) == 1, srv.slo.alerts
            assert firing_alerts[0]["slo"] == "reader-respawns"

            # the gate fails while the SLO fires...
            rc = obs_cli.main(["--watch", "--once", "--check",
                               "--root", root])
            err = capsys.readouterr().err
            assert rc == 1 and "SLO firing: reader-respawns" in err

            # ...and recovers once the respawn ages out of both windows
            deadline = time.monotonic() + 30
            while srv.slo.firing() and time.monotonic() < deadline:
                time.sleep(0.1)
            assert srv.slo.firing() == [], "respawn alert never cleared"
            assert [a["state"] for a in srv.slo.alerts] == ["firing", "ok"]
            rc = obs_cli.main(["--watch", "--once", "--check",
                               "--root", root])
            capsys.readouterr()
            assert rc == 0

            # --stats surfaces the same respawn count via the health op
            from repro.hub.service import TuningHub
            from repro.launch.hub import print_stats
            print_stats(root, hub=TuningHub(root), drift=False)
            out = capsys.readouterr().out
            assert "farm health: 2/2 alive, respawns=1 (rid 0: 1)" in out

        # shutdown stopped the monitor thread
        assert not any(t.name == "obs-sampler" and t.is_alive()
                       for t in threading.enumerate())

    def test_watch_once_renders_a_frame(self, tmp_path, capsys):
        from repro.autotune.registry import Registry
        from repro.hub.serving.server import HubServer
        from repro.hub.store import RecordStore
        from repro.launch import obs as obs_cli

        root = str(tmp_path / "hub")
        shim = types.SimpleNamespace(
            store=RecordStore(root + "/store"),
            registry=Registry(path=root + "/tuned_configs.json"))
        with HubServer(root, hub=shim, readers=1, tune_on_miss=False,
                       monitor_interval_s=0.1) as srv:
            deadline = time.monotonic() + 10
            while not srv.slo.statuses and time.monotonic() < deadline:
                time.sleep(0.05)
            rc = obs_cli.main(["--watch", "--once", "--root", root])
            out = capsys.readouterr().out
        assert rc == 0
        assert "hub serving" in out and "readers=1/1 alive" in out
        assert "latency p50" in out and "SLO:" in out
