"""Hub serving tests: byte-offset shard indexes (sidecar persistence,
stamp/schema self-invalidation, compact-under-reader), the tuned-config LRU
and latency windows, the framed socket protocol, the hub's fine-grained
read path (a slow in-flight tune must not block hits — ISSUE 7 satellite),
and the multi-process reader/writer server end to end, including the
concurrent multi-client hammer and reader kill/respawn.
"""
import dataclasses
import json
import multiprocessing as mp
import os
import socket
import threading
import time

import pytest

from repro.autotune.registry import Registry
from repro.autotune.space import ProgramConfig, Workload, default_config
from repro.hub.serving import index as idx_mod
from repro.hub.serving import protocol
from repro.hub.serving.cache import LatencyWindow, TunedConfigCache
from repro.hub.store import RecordStore, StoreSchemaError

WL_A = Workload("matmul", (256, 256, 128), name="a")
WL_B = Workload("matmul", (512, 256, 128), name="b")
CFG_A = default_config(WL_A)
CFG_B = ProgramConfig.make(block_m=64, block_n=128, block_k=128,
                           k_inner=0, unroll=1, out_bf16=1)


def _shard_of(store, device, wl):
    return store._shard_path(device, wl.key())


class TestShardIndex:
    def test_sidecar_written_on_flush(self, tmp_path):
        store = RecordStore(str(tmp_path / "s"))
        store.put("tpu_v5e", WL_A, CFG_A, 100.0)
        store.put("tpu_v5e", WL_A, CFG_B, 150.0, trial=1)
        store.flush()
        shard = _shard_of(store, "tpu_v5e", WL_A)
        sidecar = idx_mod.index_path(shard)
        assert os.path.exists(sidecar)
        st = os.stat(shard)
        idx = idx_mod.load_index(shard, (st.st_mtime_ns, st.st_size))
        assert idx is not None
        assert idx.n_records == 2 and idx.n_good == 2
        assert idx.best(WL_A.key())["throughput_gflops"] == 150.0

    def test_rows_seek_read_exact_records(self, tmp_path):
        store = RecordStore(str(tmp_path / "s"))
        for t in range(5):
            store.put("tpu_v5e", WL_A, CFG_A, 100.0 + t, trial=t)
        store.flush()
        shard = _shard_of(store, "tpu_v5e", WL_A)
        idx = store._shard_index(shard)
        rows = idx_mod.read_rows(shard, idx, 0)
        assert [r["trial"] for r in rows] == [0, 1, 2, 3, 4]
        tail = store.tail_rows("tpu_v5e", WL_A.key(), 2)
        assert [r["trial"] for r in tail] == [3, 4]

    def test_stale_sidecar_self_invalidates(self, tmp_path):
        store = RecordStore(str(tmp_path / "s"))
        store.put("tpu_v5e", WL_A, CFG_A, 100.0)
        store.flush()
        shard = _shard_of(store, "tpu_v5e", WL_A)
        # a foreign process appends a better record WITHOUT updating the
        # sidecar: the stamp no longer matches, readers must re-parse
        rec = dict(json.loads(open(shard).readline()))
        rec["throughput_gflops"] = 999.0
        rec["trial"] = 7
        with open(shard, "a") as f:
            f.write(json.dumps(rec, sort_keys=True) + "\n")
        fresh = RecordStore(str(tmp_path / "s"))
        best = fresh.best_record("tpu_v5e", WL_A.key())
        assert best["throughput_gflops"] == 999.0
        # and the rebuilt sidecar was persisted with the new stamp
        st = os.stat(shard)
        assert idx_mod.load_index(
            shard, (st.st_mtime_ns, st.st_size)) is not None

    def test_foreign_index_version_rebuilds(self, tmp_path):
        store = RecordStore(str(tmp_path / "s"))
        store.put("tpu_v5e", WL_A, CFG_A, 100.0)
        store.flush()
        shard = _shard_of(store, "tpu_v5e", WL_A)
        sidecar = idx_mod.index_path(shard)
        payload = json.load(open(sidecar))
        payload["index_version"] = 999
        json.dump(payload, open(sidecar, "w"))
        st = os.stat(shard)
        assert idx_mod.load_index(
            shard, (st.st_mtime_ns, st.st_size)) is None
        fresh = RecordStore(str(tmp_path / "s"))
        assert fresh.best_record(
            "tpu_v5e", WL_A.key())["throughput_gflops"] == 100.0

    def test_corrupt_interior_line_raises(self, tmp_path):
        store = RecordStore(str(tmp_path / "s"))
        store.put("tpu_v5e", WL_A, CFG_A, 100.0)
        store.put("tpu_v5e", WL_A, CFG_B, 150.0)
        store.flush()
        shard = _shard_of(store, "tpu_v5e", WL_A)
        lines = open(shard).read().splitlines()
        lines[0] = lines[0][: len(lines[0]) // 2]
        open(shard, "w").write("\n".join(lines) + "\n")
        with pytest.raises(StoreSchemaError):
            idx_mod.build_index(shard)

    def test_torn_trailing_line_tolerated(self, tmp_path):
        store = RecordStore(str(tmp_path / "s"))
        store.put("tpu_v5e", WL_A, CFG_A, 100.0)
        store.flush()
        shard = _shard_of(store, "tpu_v5e", WL_A)
        with open(shard, "a") as f:
            f.write('{"schema": 1, "torn')      # writer died mid-append
        idx = idx_mod.build_index(shard)
        assert idx.n_records == 1

    def test_best_record_merges_buffered(self, tmp_path):
        store = RecordStore(str(tmp_path / "s"))
        store.put("tpu_v5e", WL_A, CFG_A, 100.0)
        store.flush()
        store.put("tpu_v5e", WL_A, CFG_B, 500.0, trial=1)   # unflushed
        assert store.best_record(
            "tpu_v5e", WL_A.key())["throughput_gflops"] == 500.0

    def test_count_and_task_keys_via_index(self, tmp_path):
        store = RecordStore(str(tmp_path / "s"))
        store.put("tpu_v5e", WL_A, CFG_A, 100.0)
        store.put("tpu_v5e", WL_B, CFG_A, 75.0)
        store.put("tpu_v5e", WL_A, CFG_B, None, error="boom")
        store.flush()
        fresh = RecordStore(str(tmp_path / "s"))
        assert fresh.count("tpu_v5e") == 2
        assert fresh.count("tpu_v5e", include_errors=True) == 3
        assert fresh.task_keys("tpu_v5e") == sorted(
            [WL_A.key(), WL_B.key()])


class TestCompactIndexInvalidation:
    def _dup_shard(self, tmp_path):
        store = RecordStore(str(tmp_path / "s"))
        store.put("tpu_v5e", WL_A, CFG_A, 100.0)
        store.put("tpu_v5e", WL_A, CFG_B, 150.0, trial=1)
        store.flush()
        shard = _shard_of(store, "tpu_v5e", WL_A)
        # simulate a second process double-appending the same rows
        body = open(shard).read()
        open(shard, "a").write(body)
        return store, shard

    def test_compact_rebuilds_sidecar_atomically(self, tmp_path):
        store, shard = self._dup_shard(tmp_path)
        assert store.compact("tpu_v5e") == 2
        st = os.stat(shard)
        idx = idx_mod.load_index(shard, (st.st_mtime_ns, st.st_size))
        assert idx is not None, "compact left a stale sidecar"
        assert idx.n_records == 2
        # shard cache + idx cache agree with disk immediately
        assert store.count("tpu_v5e") == 2
        assert store.best_record(
            "tpu_v5e", WL_A.key())["throughput_gflops"] == 150.0

    def test_compact_under_concurrent_reader(self, tmp_path):
        """Readers racing a compaction must always see a consistent
        (shard, sidecar) pair: every observed best is the true winner and
        no read ever errors on a torn index."""
        store, shard = self._dup_shard(tmp_path)
        stop = threading.Event()
        failures = []

        def _reader():
            while not stop.is_set():
                r = RecordStore(os.path.dirname(
                    os.path.dirname(os.path.dirname(shard))))
                try:
                    best = r.best_record("tpu_v5e", WL_A.key())
                    n = r.count("tpu_v5e")
                except Exception as e:  # noqa: BLE001
                    failures.append(repr(e))
                    return
                if best["throughput_gflops"] != 150.0 or n not in (2, 4):
                    failures.append(f"torn view: best={best} n={n}")
                    return

        threads = [threading.Thread(target=_reader) for _ in range(3)]
        for t in threads:
            t.start()
        for _ in range(5):      # repeated duplicate + compact cycles
            body = open(shard).read()
            open(shard, "a").write(body)
            store.compact("tpu_v5e")
        stop.set()
        for t in threads:
            t.join(10.0)
        assert not failures, failures
        assert store.count("tpu_v5e") == 2


class TestTunedConfigCache:
    def test_lru_eviction_and_counters(self):
        c = TunedConfigCache(capacity=2)
        c.put("d", "a", CFG_A, 1.0)
        c.put("d", "b", CFG_B, 2.0)
        assert c.get("d", "a") == (CFG_A, 1.0)    # refreshes 'a'
        c.put("d", "c", CFG_A, 3.0)               # evicts 'b'
        assert c.get("d", "b") is None
        assert c.get("d", "a") is not None
        k = c.counters()
        assert k["evictions"] == 1 and k["hits"] == 2 and k["misses"] == 1

    def test_invalidate_by_device(self):
        c = TunedConfigCache()
        c.put("d1", "a", CFG_A, 1.0)
        c.put("d1", "b", CFG_B, 2.0)
        c.put("d2", "a", CFG_A, 3.0)
        assert c.invalidate("d1") == 2
        assert c.get("d1", "a") is None
        assert c.get("d2", "a") is not None
        assert c.invalidate("d2", "a") == 1
        assert len(c) == 0

    def test_latency_window_percentiles(self):
        w = LatencyWindow(capacity=100)
        for ms in range(1, 101):
            w.record(ms / 1e3)
        assert w.percentile(50) == pytest.approx(0.050)
        assert w.percentile(99) == pytest.approx(0.099)
        s = w.summary()
        assert s["n"] == 100 and s["p99_ms"] == pytest.approx(99.0)


class TestProtocol:
    def test_round_trip(self):
        a, b = socket.socketpair()
        with a, b:
            protocol.send_frame(a, {"op": "ping", "x": [1, 2, 3]})
            assert protocol.recv_frame(b) == {"op": "ping", "x": [1, 2, 3]}

    def test_clean_eof_is_none_torn_is_error(self):
        a, b = socket.socketpair()
        a.close()
        with b:
            assert protocol.recv_frame(b) is None
        a, b = socket.socketpair()
        with b:
            a.sendall(b"\x00\x00")                  # half a length prefix
            a.close()
            with pytest.raises(protocol.ProtocolError):
                protocol.recv_frame(b)

    def test_oversized_frame_rejected(self):
        a, b = socket.socketpair()
        with a, b:
            a.sendall((protocol.MAX_FRAME + 1).to_bytes(4, "big"))
            with pytest.raises(protocol.ProtocolError):
                protocol.recv_frame(b)

    def test_workload_config_wire_round_trip(self):
        wl = protocol.workload_from_wire(protocol.workload_to_wire(WL_A))
        assert wl == WL_A and wl.key() == WL_A.key()
        cfg = protocol.config_from_wire(protocol.config_to_wire(CFG_B))
        assert cfg.knobs == CFG_B.knobs


class TestRegistryReload:
    def test_maybe_reload_sees_foreign_save(self, tmp_path):
        path = str(tmp_path / "reg.json")
        r1 = Registry(path=path)
        r2 = Registry(path=path)
        r1.put("d", WL_A, CFG_A, 100.0)
        r1.save()
        assert r2.lookup("d", WL_A) is None         # stale until reload
        assert r2.maybe_reload() is True
        assert r2.lookup("d", WL_A)["throughput_gflops"] == 100.0
        assert r2.maybe_reload() is False           # mtime unchanged

    def test_own_save_does_not_trigger_reload(self, tmp_path):
        r = Registry(path=str(tmp_path / "reg.json"))
        r.put("d", WL_A, CFG_A, 100.0)
        r.save()
        assert r.maybe_reload() is False


# --- hub cache wiring + fine-grained read path (ISSUE 7 satellite) --------

import types  # noqa: E402

from repro.hub.service import TuningHub  # noqa: E402

DET_CFG = ProgramConfig.make(block_m=64, block_n=64, block_k=128,
                             k_inner=1, unroll=1, out_bf16=1)


class TestHubCacheWiring:
    def _hub(self, tmp_path):
        hub = TuningHub(str(tmp_path / "hub"))
        hub.registry.put("tpu_v5e", WL_A, CFG_A, 100.0)
        return hub

    def test_cache_hit_path_zero_io(self, tmp_path):
        hub = self._hub(tmp_path)
        r1 = hub.get_config("tpu_v5e", WL_A)
        assert r1.cache_hit and r1.source == "registry"
        # after the first hit the LRU holds the winner: the repeat query
        # must touch neither the registry nor the store
        hub.registry.lookup = lambda *a: pytest.fail("registry touched")
        hub.store.best_record = lambda *a: pytest.fail("store touched")
        r2 = hub.get_config("tpu_v5e", WL_A)
        assert r2.cache_hit and r2.source == "cache"
        assert r2.config.knobs == r1.config.knobs
        assert hub.stats.hits == 2 and hub.stats.cache_hits == 1
        assert hub.hit_latency.summary()["n"] == 2

    def test_tune_landing_invalidates_cache(self, tmp_path):
        hub = self._hub(tmp_path)
        hub.get_config("tpu_v5e", WL_A)
        hub.get_config("tpu_v5e", WL_A)             # now served from cache

        def fake_tune(dev, tasks):
            for wl in tasks:
                hub.registry.put(dev, wl, DET_CFG, 500.0)
            # the job also lands a better winner for the CACHED workload
            hub.registry.put(dev, WL_A, DET_CFG, 500.0)
            return types.SimpleNamespace(total_measurements=1, tasks=[])

        hub._tune_batch = fake_tune
        r = hub.get_config("tpu_v5e", WL_B)
        assert r.source == "tuned"
        # the registry write invalidated the device's cached entries: the
        # next WL_A read must serve the NEW winner, not the stale cache
        r2 = hub.get_config("tpu_v5e", WL_A)
        assert r2.source == "registry"
        assert r2.config.knobs == DET_CFG.knobs

    def test_accepted_refresh_invalidates_cache(self, tmp_path):
        hub = self._hub(tmp_path)
        hub.get_config("tpu_v5e", WL_A)
        assert len(hub.config_cache) == 1
        hub._lifecycle = types.SimpleNamespace(
            serving_params=lambda dev: object(),
            maybe_refresh=lambda dev, current_fingerprint=None:
                types.SimpleNamespace(accepted=True))
        hub._run_refresh("tpu_v5e")
        assert hub.stats.refreshes == 1
        assert len(hub.config_cache) == 0, (
            "accepted lifecycle refresh must invalidate the device's cache")

    def test_slow_inflight_miss_does_not_block_hits(self, tmp_path):
        """Satellite regression: a tune job grinding away for a device
        must not serialize registry/cache-hit reads for that same device
        behind it — the hit path takes no hub-wide or per-device lock."""
        hub = self._hub(tmp_path)
        started, release = threading.Event(), threading.Event()

        def slow_tune(dev, tasks):
            started.set()
            assert release.wait(30), "test hung"
            for wl in tasks:
                hub.registry.put(dev, wl, DET_CFG, 500.0)
            return types.SimpleNamespace(total_measurements=1, tasks=[])

        hub._tune_batch = slow_tune
        miss = threading.Thread(
            target=lambda: hub.get_config("tpu_v5e", WL_B))
        miss.start()
        assert started.wait(10), "miss never reached the tune job"
        try:
            t0 = time.perf_counter()
            r = hub.get_config("tpu_v5e", WL_A)     # same device, hit
            dt = time.perf_counter() - t0
            assert r.cache_hit, "hit path fell through during a tune"
            assert dt < 1.0, (
                f"hit took {dt:.2f}s — serialized behind the tune lock")
        finally:
            release.set()
            miss.join(30)
        assert hub.stats.hits >= 1 and hub.stats.misses == 1


# --- the multi-process server (satellite: concurrent serving) -------------

WL_C = Workload("matmul", (128, 256, 128), name="c")    # store-only task


def _fake_tune(hub, calls):
    def fake(dev, tasks):
        calls.append(sorted(wl.key() for wl in tasks))
        time.sleep(0.2)                     # widen the client race window
        for wl in tasks:
            hub.registry.put(dev, wl, DET_CFG, 321.0)
        hub.registry.save()
        with hub._stats_lock:
            hub.stats.jobs += 1
        return types.SimpleNamespace(total_measurements=len(tasks),
                                     tasks=[])
    return fake


class TestHubServer:
    def test_end_to_end_and_concurrent_hammer(self, tmp_path):
        """One server boot, three acts: (1) serving-source semantics for a
        single client; (2) N threads racing tune-on-miss for one untuned
        workload — exactly ONE tuning job runs and every thread gets the
        deterministic winner; (3) a multi-process client hammer with zero
        torn replies."""
        from benchmarks.serve_hub_bench import _bench_client_main
        from repro.hub.serving.client import HubClient
        from repro.hub.serving.server import HubServer

        root = str(tmp_path / "hub")
        hub = TuningHub(root)
        hub.registry.put("tpu_v5e", WL_A, CFG_A, 100.0)
        hub.store.put("tpu_v5e", WL_C, CFG_B, 50.0)
        hub.store.flush()
        calls = []
        hub._tune_batch = _fake_tune(hub, calls)

        with HubServer(root, hub=hub, readers=2) as srv:
            with HubClient(root=root) as c:
                assert c.ping()
                r = c.get_config("tpu_v5e", WL_A, tune=False)
                assert r.source == "registry"
                assert r.config.knobs == CFG_A.knobs
                assert c.get_config("tpu_v5e", WL_A,
                                    tune=False).source == "cache"
                r = c.get_config("tpu_v5e", WL_C, tune=False)
                assert r.source == "store"
                assert r.config.knobs == CFG_B.knobs

            # act 2: concurrent tune-on-miss funnel, one job, one winner
            results, errs = [], []

            def _query(i):
                try:
                    with HubClient(root=root, offset=i) as cl:
                        results.append(
                            cl.get_config("tpu_v5e", WL_B, tune=True))
                except Exception as e:  # noqa: BLE001
                    errs.append(repr(e))

            threads = [threading.Thread(target=_query, args=(i,))
                       for i in range(6)]
            for t in threads:
                t.start()
            for t in threads:
                t.join(60)
            assert not errs, errs
            assert len(results) == 6
            for r in results:
                assert r.config.knobs == DET_CFG.knobs, (
                    f"client saw a non-deterministic winner via {r.source}")
            assert len(calls) == 1, (
                f"in-flight dedup failed: {len(calls)} tuning jobs ran")

            # act 3: multi-process hammer over hit + store-miss paths
            ctx = mp.get_context("spawn")
            out_q = ctx.Queue()
            hit_wire = [protocol.workload_to_wire(WL_A)]
            miss_wire = [protocol.workload_to_wire(WL_C)]
            procs = [ctx.Process(target=_bench_client_main,
                                 args=(root, cid, 1.5, hit_wire, miss_wire,
                                       out_q), daemon=True)
                     for cid in range(4)]
            for p in procs:
                p.start()
            total = errors = 0
            for _ in procs:
                _cid, h, m, err = out_q.get(timeout=120)
                total += len(h) + len(m)
                errors += err
            for p in procs:
                p.join(10)
            assert errors == 0, f"{errors} torn/unexpected replies"
            assert total > 50, f"hammer barely ran: {total} requests"

            agg = srv.stats()
            assert agg["writer"]["jobs"] == 1
            assert sum(r.get("served", 0) for r in agg["readers"]) >= total

    def test_reader_kill_respawn_and_failover(self, tmp_path):
        """The farm liveness contract: a SIGKILLed reader is detected by
        the missed-heartbeat watchdog, respawned on a fresh port, and the
        endpoints file is republished so clients keep being served."""
        from repro.hub.serving.client import HubClient
        from repro.hub.serving.server import HubServer, endpoints_path

        root = str(tmp_path / "hub")
        store = RecordStore(os.path.join(root, "store"))
        reg = Registry(path=os.path.join(root, "tuned_configs.json"))
        reg.put("tpu_v5e", WL_A, CFG_A, 100.0)
        shim = types.SimpleNamespace(store=store, registry=reg)

        with HubServer(root, hub=shim, readers=2, tune_on_miss=False,
                       heartbeat_s=0.05, hb_grace_s=0.5) as srv:
            victim = srv._readers[0]
            old_port = victim.port
            victim.proc.kill()
            deadline = time.monotonic() + 30
            while time.monotonic() < deadline:
                if srv.respawns >= 1 and srv._readers[0].port != old_port:
                    break
                time.sleep(0.1)
            assert srv.respawns >= 1, "watchdog never respawned the reader"
            eps = json.load(open(endpoints_path(root)))["readers"]
            assert all(ep["port"] != old_port for ep in eps), (
                "endpoints file still advertises the dead reader")
            # a client pointed at the STALE endpoint must fail over
            with HubClient(root=root,
                           endpoints=[{"rid": 0, "port": old_port}]) as c:
                r = c.get_config("tpu_v5e", WL_A, tune=False)
                assert r.source in ("registry", "cache")
                assert r.config.knobs == CFG_A.knobs


class TestStatsColumns:
    def test_print_stats_serving_columns(self, tmp_path, capsys):
        from repro.launch.hub import print_stats

        root = str(tmp_path / "hub")
        hub = TuningHub(root)
        hub.registry.put("tpu_v5e", WL_A, CFG_A, 100.0)
        hub.get_config("tpu_v5e", WL_A)
        hub.get_config("tpu_v5e", WL_A)
        print_stats(root, hub=hub)
        out = capsys.readouterr().out
        assert "serving cache:" in out
        assert "hit-rate=0.500" in out      # 1 LRU hit / 2 lookups
        assert "p50-ms" in out and "p99-ms" in out
        # the hit row reflects the two recorded hit latencies
        hit_row = next(ln for ln in out.splitlines()
                       if ln.strip().startswith("hit "))
        assert " 2 " in hit_row
