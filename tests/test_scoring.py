"""Tests for the batched scoring + incremental feature-cache hot path:
batched_predict parity with predict across bucket boundaries, FeatureCache
hit/miss accounting, RecordsBuilder vs from-scratch Records equivalence,
padded/masked training-batch correctness, and the O(n) extract_features
call-count regression for tune()."""
import jax
import numpy as np
import pytest

import repro.core.features as features_mod
from repro.autotune.session import TuneSession, derive_job_seed
from repro.autotune.space import Workload, random_config
from repro.configs.moses import DEFAULT as MCFG
from repro.core.cost_model import (Records, RecordsBuilder, SHAPE_BUCKETS,
                                   batched_predict, bucket_size,
                                   init_mlp_params, normalize_per_task,
                                   pairwise_rank_loss, predict)
from repro.core.features import FEATURE_DIM, FeatureCache, extract_features

WL = Workload("matmul", (512, 256, 128))


@pytest.fixture(scope="module")
def params():
    return init_mlp_params(MCFG.cost_model, jax.random.PRNGKey(0))


class TestBatchedPredict:
    @pytest.mark.parametrize("n", [1, 7, 8, 9, 31, 32, 33, 128, 129, 1000])
    def test_parity_with_predict_across_bucket_boundaries(self, params, n):
        x = np.random.RandomState(n).randn(n, MCFG.cost_model.feature_dim)
        x = x.astype(np.float32)
        got = batched_predict(params, x)
        want = predict(params, x)
        assert got.shape == want.shape == (n,)
        np.testing.assert_allclose(got, want, atol=1e-6)

    def test_empty_batch(self, params):
        out = batched_predict(
            params, np.zeros((0, MCFG.cost_model.feature_dim), np.float32))
        assert out.shape == (0,)

    def test_bucket_size_is_monotone_cover(self):
        for n in range(1, 700):
            b = bucket_size(n)
            assert b >= n
            # minimal bucket: no smaller bucket would fit
            smaller = [s for s in SHAPE_BUCKETS if s < b]
            assert all(s < n for s in smaller)
        # beyond the largest bucket: rounds up to a multiple of it
        top = SHAPE_BUCKETS[-1]
        assert bucket_size(top + 1) == 2 * top


class TestFeatureCache:
    def test_hit_miss_accounting_and_correct_values(self):
        rng = np.random.RandomState(0)
        cfgs = [random_config(WL, rng) for _ in range(8)]
        cache = FeatureCache()
        first = cache.features_batch(WL, cfgs)
        assert cache.misses == len({c.knobs for c in cfgs})
        hits_before = cache.hits
        second = cache.features_batch(WL, cfgs)
        assert cache.misses == len({c.knobs for c in cfgs})  # no re-extraction
        assert cache.hits == hits_before + len(cfgs)
        np.testing.assert_array_equal(first, second)
        for c, row in zip(cfgs, first):
            np.testing.assert_array_equal(row, extract_features(WL, c))

    def test_distinguishes_workloads_with_same_config_knobs(self):
        wl2 = Workload("matmul", (1024, 256, 128))
        rng = np.random.RandomState(1)
        cfg = random_config(WL, rng)
        cache = FeatureCache()
        f1 = cache.features(WL, cfg)
        f2 = cache.features(wl2, cfg)
        assert cache.misses == 2
        assert not np.array_equal(f1, f2)

    def test_empty_batch_shape(self):
        cache = FeatureCache()
        out = cache.features_batch(WL, [])
        assert out.shape == (0, FEATURE_DIM)

    def test_honors_monkeypatched_extractor(self, monkeypatch):
        calls = []

        def fake(wl, cfg):
            calls.append(cfg.knobs)
            return np.zeros(FEATURE_DIM, np.float32)

        monkeypatch.setattr(features_mod, "extract_features", fake)
        cache = FeatureCache()
        cfg = random_config(WL, np.random.RandomState(2))
        cache.features(WL, cfg)
        cache.features(WL, cfg)
        assert calls == [cfg.knobs]


class TestRecordsBuilder:
    def test_matches_from_scratch_records(self):
        rng = np.random.RandomState(0)
        builder = RecordsBuilder()
        feats, raws, gs = [], [], []
        for i in range(17):
            f = rng.randn(FEATURE_DIM).astype(np.float32)
            raw = float(rng.rand() + 0.1)
            g = i % 3
            builder.append(f, raw, group=g)
            feats.append(f)
            raws.append(raw)
            gs.append(g)
            # snapshot mid-stream must equal a from-scratch build every time
            snap = builder.snapshot()
            raw_arr = np.asarray(raws, np.float32)
            g_arr = np.asarray(gs, np.int32)
            np.testing.assert_array_equal(snap.x, np.stack(feats))
            np.testing.assert_array_equal(snap.g, g_arr)
            np.testing.assert_allclose(
                snap.y, normalize_per_task(raw_arr, g_arr))
        assert len(builder) == 17

    def test_empty_snapshot_raises(self):
        with pytest.raises(AssertionError):
            RecordsBuilder().snapshot()


class TestPaddedBatches:
    def test_padded_batches_have_bucket_shapes_and_masks(self):
        n = 45
        rec = Records(x=np.ones((n, 4), np.float32),
                      y=np.ones(n, np.float32),
                      g=np.zeros(n, np.int32))
        batches = list(rec.batches(32, np.random.RandomState(0), pad=True))
        assert [len(b["x"]) for b in batches] == [32, 16]  # 13 -> bucket 16
        tail = batches[-1]
        m = np.asarray(tail["m"])
        assert m.sum() == 13
        assert np.all(np.asarray(tail["g"])[m == 0] == -1)
        assert np.all(np.asarray(tail["x"])[m == 0] == 0)

    def test_rank_loss_ignores_padded_rows(self):
        rng = np.random.RandomState(0)
        scores = rng.randn(16).astype(np.float32)
        labels = rng.rand(16).astype(np.float32)
        g = np.zeros(16, np.int32)
        key = jax.random.PRNGKey(0)
        base = float(pairwise_rank_loss(scores, labels, g, key,
                                        valid=np.ones(16, np.float32)))
        # corrupt the "padded" half: same loss as masking it out requires the
        # padded rows to carry g=-1 AND m=0 (both are applied by batches())
        scores2 = np.concatenate([scores, rng.randn(16).astype(np.float32)])
        labels2 = np.concatenate([labels, rng.rand(16).astype(np.float32)])
        g2 = np.concatenate([g, np.full(16, -1, np.int32)])
        m2 = np.concatenate([np.ones(16), np.zeros(16)]).astype(np.float32)
        # pair sampling depends on B, so compare against the same 32-row
        # tensor with the pad rows made valid vs masked: masked must differ
        # from unmasked (mask has effect) and must never pair pad rows
        masked = float(pairwise_rank_loss(scores2, labels2, g2, key, valid=m2))
        assert np.isfinite(masked)
        # all-pad mask yields the 0/1 guard value, not NaN
        allpad = float(pairwise_rank_loss(
            scores2, labels2, g2, key, valid=np.zeros(32, np.float32)))
        assert allpad == 0.0
        assert np.isfinite(base)


class TestTuneCallCount:
    def test_extract_features_called_once_per_distinct_config(
            self, monkeypatch):
        """The regression guard for the O(n^2) -> O(n) refactor: over a full
        tune() run, no (task, config) pair is featurized more than once, and
        every measured config was featurized exactly once."""
        calls = {}
        real = extract_features

        def counting(wl, cfg):
            k = (wl.key(), cfg.knobs)
            calls[k] = calls.get(k, 0) + 1
            return real(wl, cfg)

        monkeypatch.setattr(features_mod, "extract_features", counting)
        params = init_mlp_params(MCFG.cost_model, jax.random.PRNGKey(0))
        tasks = [Workload("matmul", (256, 256, 128), name="a"),
                 Workload("matmul", (128, 512, 128), name="b")]
        session = TuneSession(moses_cfg=MCFG, pretrained_params=params,
                              seed=0)
        r = session.run(tasks, "tpu_v5e", "moses", trials_per_task=16)
        assert calls, "counting wrapper never engaged"
        assert max(calls.values()) == 1, (
            "some config featurized more than once: "
            f"{[k for k, v in calls.items() if v > 1][:3]}")
        # every measured config appears in the call log exactly once
        for tr in r.tasks:
            assert calls.get(
                (tr.workload.key(), tr.best_config.knobs)) == 1
        # and the total is O(n): bounded by distinct configs evaluated
        assert sum(calls.values()) == len(calls)


class TestTuneSession:
    def test_job_seeds_isolated_and_order_independent(self):
        s = TuneSession(seed=7)
        a = s.job_seed("tpu_v5e", "moses")
        b = s.job_seed("tpu_edge", "moses")
        c = s.job_seed("tpu_v5e", "tenset-finetune")
        assert len({a, b, c}) == 3
        assert a == derive_job_seed(7, "tpu_v5e", "moses")
        s2 = TuneSession(seed=7, isolate_rng=False)
        assert s2.job_seed("tpu_v5e", "moses") == 7

    def test_session_runs_and_ingests_registry(self, tmp_path):
        from repro.autotune.registry import Registry
        reg = Registry(path=str(tmp_path / "tuned.json"))
        params = init_mlp_params(MCFG.cost_model, jax.random.PRNGKey(0))
        tasks = [Workload("matmul", (256, 256, 128), name="a")]
        session = TuneSession(moses_cfg=MCFG, pretrained_params=params,
                              seed=3, registry=reg)
        r = session.run(tasks, "tpu_v5e", "tenset-pretrain",
                        trials_per_task=8)
        assert session.results == [r]
        got = reg.get("tpu_v5e", tasks[0])
        assert got.knobs == r.tasks[0].best_config.knobs

    def test_registry_ingest_many_keeps_better_config(self, tmp_path):
        from repro.autotune.registry import Registry
        from repro.autotune.space import default_config
        from repro.autotune.tuner import TaskResult, TuneResult
        wl = Workload("matmul", (256, 256, 128), name="a")
        cfg_lo, cfg_hi = default_config(wl), default_config(
            Workload("matmul", (512, 512, 512)))
        lo = TuneResult("moses", "tpu_v5e", [
            TaskResult(wl, cfg_lo, 100.0, 1e-3, 1, 0.0, [])], 0.0)
        hi = TuneResult("tenset-finetune", "tpu_v5e", [
            TaskResult(wl, cfg_hi, 200.0, 5e-4, 1, 0.0, [])], 0.0)
        reg = Registry(path=str(tmp_path / "tuned.json"))
        reg.ingest_many([hi, lo], save=True)  # worse result ingested last
        assert reg.get("tpu_v5e", wl).knobs == cfg_hi.knobs
        # persisted via save=True
        reloaded = Registry(path=str(tmp_path / "tuned.json"))
        assert reloaded.get("tpu_v5e", wl).knobs == cfg_hi.knobs
