"""Tests for the auto-tuning substrate: space, devices, evolution, features,
tuner invariants. Includes hypothesis property tests (skipped when
hypothesis is not installed; see _hypothesis_support)."""
import numpy as np
import pytest
from _hypothesis_support import given, settings, st

from repro.autotune import devices as dev_mod
from repro.autotune.evolution import evolutionary_search
from repro.autotune.space import (ProgramConfig, Workload, config_valid,
                                  default_config, knob_space, mutate_config,
                                  random_config, vmem_working_set)
from repro.autotune.tasks import (arch_tasks, paper_dnn_tasks,
                                  PAPER_DNN_NAMES)
from repro.core.features import FEATURE_DIM, extract_features

WL_MM = Workload("matmul", (512, 256, 128))
WL_AT = Workload("attention", (1024, 64))
WL_SC = Workload("scan", (2048, 512))
ALL_WLS = [WL_MM, WL_AT, WL_SC]


class TestSpace:
    @pytest.mark.parametrize("wl", ALL_WLS)
    def test_random_configs_are_valid(self, wl):
        rng = np.random.RandomState(0)
        for _ in range(50):
            assert config_valid(wl, random_config(wl, rng))

    @pytest.mark.parametrize("wl", ALL_WLS)
    def test_mutation_stays_in_space(self, wl):
        rng = np.random.RandomState(0)
        cfg = default_config(wl)
        for _ in range(50):
            cfg = mutate_config(wl, cfg, rng)
            assert config_valid(wl, cfg)

    @given(st.integers(0, 10_000))
    @settings(max_examples=30, deadline=None)
    def test_vmem_working_set_positive_and_monotone_in_blocks(self, seed):
        rng = np.random.RandomState(seed)
        cfg = random_config(WL_MM, rng)
        ws = vmem_working_set(WL_MM, cfg)
        assert ws > 0
        d = cfg.as_dict()
        space = knob_space(WL_MM)
        if d["block_m"] < max(space["block_m"]):
            bigger = dict(d)
            bigger["block_m"] = max(space["block_m"])
            ws2 = vmem_working_set(
                WL_MM, ProgramConfig(tuple(sorted(bigger.items()))))
            assert ws2 >= ws


class TestDevices:
    @pytest.mark.parametrize("wl", ALL_WLS)
    @pytest.mark.parametrize("device", list(dev_mod.DEVICES))
    def test_measure_positive_finite(self, wl, device):
        rng = np.random.RandomState(0)
        for _ in range(10):
            thr = dev_mod.measure(wl, random_config(wl, rng), device)
            assert np.isfinite(thr) and thr > 0

    def test_noise_is_deterministic_per_trial(self):
        cfg = default_config(WL_MM)
        a = dev_mod.measure(WL_MM, cfg, "tpu_v5e", trial=3)
        b = dev_mod.measure(WL_MM, cfg, "tpu_v5e", trial=3)
        c = dev_mod.measure(WL_MM, cfg, "tpu_v5e", trial=4)
        assert a == b
        assert a != c

    def test_throughput_below_peak(self):
        rng = np.random.RandomState(0)
        for device, dev in dev_mod.DEVICES.items():
            for _ in range(20):
                cfg = random_config(WL_MM, rng)
                thr = dev_mod.measure(WL_MM, cfg, device, noisy=False)
                assert thr * 1e9 <= dev.peak_flops * 1.01

    def test_devices_rank_configs_differently(self):
        """The transfer gap exists: per-device optima differ (Eq. 3's
        hardware-dependent component)."""
        rng = np.random.RandomState(0)
        cfgs = [random_config(WL_MM, rng) for _ in range(200)]
        best = {}
        for device in ("tpu_v5p", "tpu_edge"):
            thr = [dev_mod.measure(WL_MM, c, device, noisy=False)
                   for c in cfgs]
            best[device] = cfgs[int(np.argmax(thr))]
        assert best["tpu_v5p"].knobs != best["tpu_edge"].knobs

    def test_vmem_spill_penalized(self):
        big = ProgramConfig.make(block_m=1024, block_n=1024, block_k=2048,
                                 k_inner=1, unroll=1, out_bf16=1)
        small = ProgramConfig.make(block_m=128, block_n=128, block_k=128,
                                   k_inner=0, unroll=1, out_bf16=1)
        wl = Workload("matmul", (2048, 2048, 2048))
        t_big = dev_mod.execution_time(wl, big, dev_mod.DEVICES["tpu_edge"],
                                       noisy=False)
        t_small = dev_mod.execution_time(wl, small,
                                         dev_mod.DEVICES["tpu_edge"],
                                         noisy=False)
        assert t_big > t_small


class TestFeatures:
    @pytest.mark.parametrize("wl", ALL_WLS)
    def test_feature_dim_is_164(self, wl):
        rng = np.random.RandomState(0)
        f = extract_features(wl, random_config(wl, rng))
        assert f.shape == (FEATURE_DIM,) == (164,)
        assert np.all(np.isfinite(f))

    def test_features_distinguish_configs(self):
        rng = np.random.RandomState(0)
        a, b = random_config(WL_MM, rng), random_config(WL_MM, rng)
        assert a.knobs != b.knobs
        fa = extract_features(WL_MM, a)
        fb = extract_features(WL_MM, b)
        assert not np.allclose(fa, fb)

    @given(st.integers(0, 10_000))
    @settings(max_examples=30, deadline=None)
    def test_features_deterministic(self, seed):
        rng = np.random.RandomState(seed)
        cfg = random_config(WL_MM, rng)
        f1 = extract_features(WL_MM, cfg)
        f2 = extract_features(WL_MM, cfg)
        np.testing.assert_array_equal(f1, f2)


class TestEvolution:
    def test_search_beats_random_with_oracle_scores(self):
        """With the true device as score function the search finds better
        configs than random sampling at equal budget."""
        rng = np.random.RandomState(0)
        from repro.core.features import extract_features as ef

        def oracle(feats):
            # invert: features don't carry the config, so score via measure
            return np.zeros(len(feats))

        # use measure-backed scoring through a wrapper around configs
        cfgs_random = [random_config(WL_MM, np.random.RandomState(i))
                       for i in range(64)]
        thr_random = max(dev_mod.measure(WL_MM, c, "tpu_v5e", noisy=False)
                         for c in cfgs_random)

        # evolutionary search with the simulator as a (cheating) oracle: just
        # verify it returns valid, deduped configs and includes good ones
        seen = set()
        best_cfgs = evolutionary_search(
            WL_MM,
            lambda feats: np.asarray([f[72] for f in feats]),  # log-flops proxy
            rng, population=64, rounds=3, top_k=16, seen=seen)
        assert len(best_cfgs) == 16
        assert len({c.knobs for c in best_cfgs}) == 16
        for c in best_cfgs:
            assert config_valid(WL_MM, c)

    def test_seen_configs_never_resampled(self):
        rng = np.random.RandomState(0)
        seen = set()
        a = evolutionary_search(WL_MM, lambda f: np.zeros(len(f)), rng,
                                population=32, rounds=1, top_k=8, seen=seen)
        b = evolutionary_search(WL_MM, lambda f: np.zeros(len(f)), rng,
                                population=32, rounds=1, top_k=8, seen=seen)
        assert not ({c.knobs for c in a} & {c.knobs for c in b})


class TestTasks:
    @pytest.mark.parametrize("name", PAPER_DNN_NAMES)
    def test_paper_dnn_tasks_nonempty(self, name):
        tasks = paper_dnn_tasks(name)
        assert len(tasks) >= 6
        for t in tasks:
            assert t.flops > 0 and t.count >= 1

    def test_squeezenet_has_23_tasks(self):
        assert len(paper_dnn_tasks("squeezenet")) == 23

    def test_arch_task_extraction_covers_all_archs(self):
        from repro.configs import ARCH_IDS, get_config
        for a in ARCH_IDS:
            tasks = arch_tasks(get_config(a))
            assert len(tasks) >= 3, a
            kinds = {t.kind for t in tasks}
            assert "matmul" in kinds
            if a in ("recurrentgemma-2b", "xlstm-350m"):
                assert "scan" in kinds


class TestRegistryRoundTrip:
    """Tuned-config Registry persistence invariants: ingest -> save -> load
    preserves winners, and collisions keep the better config regardless of
    ingest order."""

    def _result(self, wl, device, knobs, throughput):
        from repro.autotune.space import ProgramConfig
        from repro.autotune.tuner import TaskResult, TuneResult
        cfg = ProgramConfig(tuple(sorted(knobs.items())))
        task = TaskResult(wl, cfg, throughput, 1.0 / max(throughput, 1e-9),
                          1, 0.0, [throughput])
        return TuneResult("moses", device, [task], 0.0)

    def test_ingest_save_load_preserves_winners(self, tmp_path):
        from repro.autotune.registry import Registry
        wl_a = Workload("matmul", (128, 128, 128), name="a")
        wl_b = Workload("matmul", (256, 128, 128), name="b")
        knobs_a = {"block_m": 128, "block_n": 128, "block_k": 128,
                   "k_inner": 0, "unroll": 1, "out_bf16": 0}
        knobs_b = dict(knobs_a, block_m=64)
        path = str(tmp_path / "tuned.json")
        reg = Registry(path=path)
        reg.ingest(self._result(wl_a, "tpu_v5e", knobs_a, 100.0))
        reg.ingest(self._result(wl_b, "tpu_v5e", knobs_b, 50.0))
        reg.ingest(self._result(wl_a, "tpu_edge", knobs_b, 10.0))
        reg.save()
        loaded = Registry(path=path)
        assert loaded.get("tpu_v5e", wl_a).as_dict() == knobs_a
        assert loaded.get("tpu_v5e", wl_b).as_dict() == knobs_b
        assert loaded.get("tpu_edge", wl_a).as_dict() == knobs_b
        # unknown workloads fall back to the vendor default
        wl_new = Workload("matmul", (512, 512, 512), name="new")
        assert loaded.get("tpu_v5e", wl_new).knobs == \
            default_config(wl_new).knobs

    @pytest.mark.parametrize("better_first", [True, False])
    def test_collision_keeps_better_either_order(self, tmp_path,
                                                 better_first):
        from repro.autotune.registry import Registry
        wl = Workload("matmul", (128, 128, 128), name="a")
        worse = {"block_m": 64, "block_n": 128, "block_k": 128,
                 "k_inner": 0, "unroll": 1, "out_bf16": 0}
        better = dict(worse, block_m=128)
        results = [self._result(wl, "tpu_v5e", better, 200.0),
                   self._result(wl, "tpu_v5e", worse, 100.0)]
        if not better_first:
            results.reverse()
        reg = Registry(path=str(tmp_path / "tuned.json"))
        reg.ingest_many(results, save=True)
        loaded = Registry(path=str(tmp_path / "tuned.json"))
        assert loaded.get("tpu_v5e", wl).as_dict() == better


class TestCrossTaskTransfer:
    """Beyond-paper extension (paper §5 future work): cross-subgraph
    warm-starting via the cross_task archive."""

    def test_clip_config_to_space(self):
        from repro.autotune.space import clip_config_to_space
        src_wl = Workload("matmul", (4096, 4096, 4096))
        dst_wl = Workload("matmul", (64, 64, 64))
        rng = np.random.RandomState(0)
        cfg = random_config(src_wl, rng)
        clipped = clip_config_to_space(dst_wl, cfg)
        assert clipped is not None
        assert config_valid(dst_wl, clipped)
        # cross-kind transfer drops cleanly
        assert clip_config_to_space(WL_SC, cfg) is None

    def test_cross_task_tune_runs_and_matches_contract(self):
        import jax
        from repro.autotune.tuner import tune
        from repro.configs.moses import DEFAULT as MCFG
        from repro.core.cost_model import init_mlp_params
        tasks = [Workload("matmul", (256, 256, 128), name="a"),
                 Workload("matmul", (256, 512, 128), name="b")]
        params = init_mlp_params(MCFG.cost_model, jax.random.PRNGKey(0))
        r = tune(tasks, "tpu_v5e", "moses", MCFG, trials_per_task=16,
                 pretrained_params=params, seed=0, cross_task=True)
        assert len(r.tasks) == 2
        for t in r.tasks:
            assert t.best_throughput > 0
            assert config_valid(t.workload, t.best_config)
