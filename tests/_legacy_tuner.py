"""Frozen copy of the PRE-REFACTOR `tune()` (PR 1 state, git 74fb702),
kept verbatim as the reference implementation for the string-strategy parity
test: the registry-resolved Strategy/CostModel path must produce bit-identical
`TuneResult`s to this if/elif ladder on a fixed seed. Only the module
docstring and the result-class imports differ from the historical file (the
dataclasses are shared with the live tuner so results compare directly).

Not part of the library — test support only.
"""

from __future__ import annotations

import copy
import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.autotune import devices as dev_mod
from repro.autotune.evolution import evolutionary_search
from repro.autotune.space import (ProgramConfig, Workload, default_config,
                                  random_config)
from repro.configs.moses import MosesConfig
from repro.core.ac import ACState, AdaptiveController
from repro.core.adaptation import MosesAdapter
from repro.core.cost_model import (Records, RecordsBuilder, batched_predict,
                                   init_mlp_params, train_cost_model)
from repro.core.features import FeatureCache

STRATEGIES = ("raw", "ansor-random", "tenset-pretrain", "tenset-finetune",
              "moses")


from repro.autotune.tuner import TaskResult, TuneResult  # noqa: E402


def _noiseless_latency(wl: Workload, cfg: ProgramConfig, device: str) -> float:
    return dev_mod.execution_time(wl, cfg, dev_mod.DEVICES[device],
                                  noisy=False)


def legacy_tune(
    tasks: Sequence[Workload],
    device: str,
    strategy: str,
    moses_cfg: MosesConfig,
    trials_per_task: int = 200,
    pretrained_params=None,
    source_pool: Optional[Records] = None,
    seed: int = 0,
    ratio_override: Optional[float] = None,
    model_update_cost: float = 2.0,
    cross_task: bool = False,
) -> TuneResult:
    assert strategy in STRATEGIES, strategy
    rng = np.random.RandomState(seed)
    cm_cfg = moses_cfg.cost_model

    # --- cost model initialization per strategy
    params = None
    adapter = None
    if strategy == "ansor-random":
        params = init_mlp_params(cm_cfg, jax.random.PRNGKey(seed))
    elif strategy in ("tenset-pretrain", "tenset-finetune"):
        assert pretrained_params is not None
        params = copy.deepcopy(pretrained_params)
    elif strategy == "moses":
        assert pretrained_params is not None
        adapter = MosesAdapter(cfg=moses_cfg,
                               params=copy.deepcopy(pretrained_params),
                               source_pool=source_pool,
                               ratio_override=ratio_override)
        params = adapter.params

    ac = AdaptiveController(moses_cfg.ac_train_ratio, moses_cfg.ac_num_batches,
                            moses_cfg.ac_cv_threshold)

    task_results: List[TaskResult] = []
    total_search = 0.0
    # cross-task transfer archive (paper's stated future work; see
    # benchmarks/crosstask.py): (descriptor, best configs) of finished tasks
    archive: List = []

    for gid, wl in enumerate(tasks):
        seen: set = set()
        measured: List[Tuple[ProgramConfig, float]] = []
        traj: List[float] = []
        best_thr = float("-inf")    # running best-so-far for the trajectory
        search_s = 0.0
        # per-task feature cache + incremental record builder: every config a
        # scoring or training pass touches is featurized exactly once
        cache = FeatureCache()
        builder = RecordsBuilder()

        if strategy == "raw":
            cfg = default_config(wl)
            lat = _noiseless_latency(wl, cfg, device)
            task_results.append(TaskResult(wl, cfg, wl.flops / lat / 1e9, lat,
                                           0, 0.0, []))
            continue

        def score_fn(feats: np.ndarray) -> np.ndarray:
            if params is None:
                return rng.rand(len(feats))
            return batched_predict(params, feats)

        # measurement plan
        if strategy == "moses":
            batch_sizes, n_pred = ac.plan(trials_per_task)
            ac_state = ACState()
        else:
            per_round = moses_cfg.top_k_measure
            n_meas = trials_per_task
            batch_sizes = [per_round] * max(1, n_meas // per_round)
            n_pred = 0

        warm_seeds: List[ProgramConfig] = []
        if cross_task and archive:
            from repro.autotune.space import (clip_config_to_space,
                                              workload_descriptor)
            desc = workload_descriptor(wl)
            sims = [(float(np.linalg.norm(desc - d)), cfgs)
                    for d, cfgs in archive]
            _, best_cfgs = min(sims, key=lambda t: t[0])
            for c in best_cfgs:
                cc = clip_config_to_space(wl, c)
                if cc is not None and cc.knobs not in seen:
                    warm_seeds.append(cc)

        for bi, bsz in enumerate(batch_sizes):
            cands = evolutionary_search(
                wl, score_fn, rng,
                population=moses_cfg.population_size,
                rounds=moses_cfg.evolution_rounds,
                mutation_prob=moses_cfg.mutation_prob,
                top_k=bsz, eps_greedy=moses_cfg.eps_greedy, seen=seen,
                seed_configs=(warm_seeds if (bi == 0 and not measured) else [])
                + [c for c, _ in sorted(measured, key=lambda t: -t[1])[:8]],
                feature_cache=cache)
            if not cands:  # config space exhausted
                break
            feats = cache.features_batch(wl, cands)
            thr = np.array([dev_mod.measure(wl, c, device, trial=bi)
                            for c in cands], np.float32)
            for c, t, f in zip(cands, thr, feats):
                measured.append((c, float(t)))
                builder.append(f, float(t))
                best_thr = max(best_thr, float(t))
                traj.append(best_thr)
            search_s += sum(dev_mod.measurement_seconds(wl, c, device)
                            for c in cands)

            # online model update on the incremental record set (features were
            # extracted once at measurement time; only labels re-normalize);
            # snapshot only for strategies that train on it
            if strategy in ("ansor-random", "tenset-finetune"):
                params, _ = train_cost_model(params, builder.snapshot(),
                                             cm_cfg,
                                             epochs=moses_cfg.online_epochs,
                                             seed=seed + bi, pad=True)
                search_s += model_update_cost
            elif strategy == "moses":
                adapter.adapt(builder.snapshot(),
                              epochs=moses_cfg.online_epochs)
                params = adapter.params
                search_s += model_update_cost
                preds = batched_predict(params, feats)
                ac_state = ac.update(ac_state, preds)
                if ac_state.terminated:
                    # early-terminate hardware measurement; remaining trials
                    # are pure cost-model predictions (paper §3.5)
                    n_pred += sum(batch_sizes[bi + 1:])
                    break
            # tenset-pretrain never updates

        # prediction-only trials: explore with the (adapted) cost model and
        # accept its argmax WITHOUT measuring (zero hardware cost)
        if n_pred > 0 and params is not None:
            cands = evolutionary_search(
                wl, score_fn, rng, population=moses_cfg.population_size,
                rounds=moses_cfg.evolution_rounds, top_k=n_pred, seen=seen,
                feature_cache=cache)
            cands = cands or [default_config(wl)]
            scores = batched_predict(params, cache.features_batch(wl, cands))
            top = cands[int(np.argmax(scores))]
            # top-1 predicted config gets one confirmation measurement
            thr = dev_mod.measure(wl, top, device, trial=97)
            measured.append((top, float(thr)))
            best_thr = max(best_thr, float(thr))
            traj.append(best_thr)
            search_s += dev_mod.measurement_seconds(wl, top, device)

        best_cfg, _ = max(measured, key=lambda t: t[1])
        lat = _noiseless_latency(wl, best_cfg, device)
        task_results.append(TaskResult(
            wl, best_cfg, wl.flops / lat / 1e9, lat,
            len(measured), search_s, traj))
        total_search += search_s
        if cross_task:
            from repro.autotune.space import workload_descriptor
            top4 = [c for c, _ in sorted(measured, key=lambda t: -t[1])[:4]]
            archive.append((workload_descriptor(wl), top4))

    return TuneResult(strategy, device, task_results, total_search)
