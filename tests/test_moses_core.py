"""Unit + property tests for the paper's core: lottery masks (Eq. 5/7),
adaptive controller (§3.5), adaptation (§3.4), cost model."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_support import given, settings, st

from repro.configs.moses import DEFAULT as MCFG, CostModelConfig, MosesConfig
from repro.core import lottery
from repro.core.ac import ACState, AdaptiveController
from repro.core.adaptation import MosesAdapter
from repro.core.cost_model import (Records, init_mlp_params, mlp_forward,
                                   normalize_per_task, predict,
                                   rank_correlation, train_cost_model)


def _toy_params(key=0):
    k = jax.random.PRNGKey(key)
    return {"w0": jax.random.normal(k, (8, 4)),
            "b0": jax.random.normal(jax.random.fold_in(k, 1), (4,))}


def _toy_grads(params, key=1):
    k = jax.random.PRNGKey(key)
    return jax.tree.map(
        lambda p: jax.random.normal(jax.random.fold_in(k, p.size), p.shape),
        params)


class TestLottery:
    def test_xi_is_elementwise_abs_product(self):
        p = _toy_params()
        g = _toy_grads(p)
        xi = lottery.xi_scores(p, g)
        np.testing.assert_allclose(np.asarray(xi["w0"]),
                                   np.abs(np.asarray(p["w0"] * g["w0"])))

    @given(ratio=st.floats(0.01, 0.99))
    @settings(max_examples=20, deadline=None)
    def test_ratio_mask_fraction_property(self, ratio):
        """mask_by_ratio selects ~ratio of all parameters (hypothesis)."""
        p = _toy_params()
        g = _toy_grads(p)
        mask = lottery.transferable_mask(p, g, ratio=ratio, use_ratio=True)
        frac = lottery.mask_fraction(mask)
        n = sum(x.size for x in jax.tree.leaves(p))
        assert abs(frac - ratio) <= 1.5 / n + 0.03

    def test_degenerate_equal_scores_mask_all_transferable(self):
        """Regression: when every xi is equal there is no ranking signal —
        normalization used to map all scores to 0, collapsing theta-mode
        masks to all-variant (the whole model decays toward zero). The guard
        must treat every parameter as transferable instead."""
        scores = {"w0": jnp.full((4, 3), 0.7), "b0": jnp.full((3,), 0.7)}
        norm = lottery.normalize_scores(scores)
        for leaf in jax.tree.leaves(norm):
            np.testing.assert_array_equal(np.asarray(leaf),
                                          np.ones_like(np.asarray(leaf)))
        mask = lottery.mask_by_threshold(scores, theta=0.5)
        assert lottery.mask_fraction(mask) == 1.0
        # all-zero scores (e.g. a zero gradient step) hit the same guard
        zero = {"w0": jnp.zeros((4, 3))}
        m0 = lottery.mask_by_threshold(zero, theta=0.5)
        assert lottery.mask_fraction(m0) == 1.0
        # under jit too: the guard is a traced jnp.where, not a python branch
        m_jit = jax.jit(lambda s: lottery.mask_by_threshold(s, 0.5))(scores)
        assert lottery.mask_fraction(m_jit) == 1.0

    def test_normalization_unchanged_when_scores_differ(self):
        """The degenerate guard must not perturb the normal path."""
        p = _toy_params()
        g = _toy_grads(p)
        scores = lottery.xi_scores(p, g)
        norm = lottery.normalize_scores(scores)
        flat = np.concatenate([np.asarray(s).ravel()
                               for s in jax.tree.leaves(norm)])
        assert flat.min() == 0.0 and flat.max() == 1.0

    def test_threshold_mask_monotone(self):
        p = _toy_params()
        g = _toy_grads(p)
        scores = lottery.xi_scores(p, g)
        m_low = lottery.mask_by_threshold(scores, 0.1)
        m_high = lottery.mask_by_threshold(scores, 0.9)
        assert lottery.mask_fraction(m_low) >= lottery.mask_fraction(m_high)

    def test_variant_params_decay_invariant_params_update(self):
        p = {"w": jnp.array([1.0, 1.0])}
        updates = {"w": jnp.array([0.5, 0.5])}
        mask = {"w": jnp.array([1.0, 0.0])}
        new = lottery.masked_update(p, updates, mask, variant_decay=0.1,
                                    lr=1.0)
        assert float(new["w"][0]) == pytest.approx(1.5)   # invariant: updated
        assert float(new["w"][1]) == pytest.approx(0.9)   # variant: decayed

    @given(decay=st.floats(0.01, 0.5), steps=st.integers(1, 30))
    @settings(max_examples=15, deadline=None)
    def test_variant_decay_converges_to_zero(self, decay, steps):
        """Eq. 7: repeated variant decay is a contraction toward 0."""
        w = {"w": jnp.ones((4,))}
        mask = {"w": jnp.zeros((4,))}
        upd = {"w": jnp.zeros((4,))}
        for _ in range(steps):
            w = lottery.masked_update(w, upd, mask, decay, lr=1.0)
        assert float(jnp.abs(w["w"]).max()) <= (1 - decay) ** steps + 1e-6


class TestAC:
    def test_plan_splits_budget(self):
        ac = AdaptiveController(train_ratio=0.5, num_batches=4)
        sizes, n_pred = ac.plan(200)
        assert sum(sizes) == 100 and n_pred == 100
        assert len(sizes) == 4

    def test_terminates_on_stable_predictions(self):
        ac = AdaptiveController(cv_threshold=0.1, min_batches=2)
        s = ACState()
        s = ac.update(s, np.array([1.0, 1.0]))
        assert not s.terminated
        s = ac.update(s, np.array([1.01, 0.99]))
        assert s.terminated

    def test_keeps_measuring_when_uncertain(self):
        ac = AdaptiveController(cv_threshold=0.01, min_batches=2)
        s = ACState()
        for v in (1.0, 3.0, 0.2, 2.5):
            s = ac.update(s, np.array([v]))
        assert not s.terminated

    @given(st.lists(st.floats(0.5, 2.0), min_size=4, max_size=8))
    @settings(max_examples=25, deadline=None)
    def test_cv_threshold_property(self, means):
        """AC terminates iff the running CV over batch means < threshold."""
        ac = AdaptiveController(cv_threshold=0.08, min_batches=len(means))
        s = ACState()
        for m in means:
            s = ac.update(s, np.array([m]))
        cv = np.std(means) / max(abs(np.mean(means)), 1e-9)
        assert s.terminated == (cv < 0.08)


def _synth_records(n_tasks=6, per_task=40, seed=0, flip=False):
    """Synthetic records with a learnable linear structure."""
    rng = np.random.RandomState(seed)
    w = rng.randn(MCFG.cost_model.feature_dim)
    if flip:
        w = -w
    xs, ys, gs = [], [], []
    for g in range(n_tasks):
        x = rng.randn(per_task, MCFG.cost_model.feature_dim).astype(np.float32)
        raw = (x @ w + 0.1 * rng.randn(per_task)).astype(np.float32)
        raw = np.exp(raw / (np.abs(raw).max() + 1e-6))
        xs.append(x)
        ys.append(raw)
        gs.append(np.full(per_task, g, np.int32))
    x = np.concatenate(xs)
    raw = np.concatenate(ys)
    g = np.concatenate(gs)
    return Records(x=x, y=normalize_per_task(raw, g), g=g, raw_throughput=raw)


class TestCostModel:
    def test_training_improves_rank_correlation(self):
        rec = _synth_records()
        params = init_mlp_params(MCFG.cost_model, jax.random.PRNGKey(0))
        before = rank_correlation(params, rec)
        params, losses = train_cost_model(params, rec, MCFG.cost_model,
                                          epochs=10)
        after = rank_correlation(params, rec)
        assert after > max(before, 0.5)
        assert losses[-1] < losses[0]

    def test_hidden_layer_exposed_for_discriminator(self):
        params = init_mlp_params(MCFG.cost_model, jax.random.PRNGKey(0))
        x = jnp.zeros((3, MCFG.cost_model.feature_dim))
        s, h = mlp_forward(params, x, return_hidden=True)
        assert s.shape == (3,)
        assert h.shape == (3, MCFG.cost_model.hidden_dims[-1])


class TestAdaptation:
    def test_moses_adapts_better_than_frozen_on_flipped_domain(self):
        """Target domain reverses the ranking signal on part of the features;
        Moses adaptation must beat the frozen source model."""
        src = _synth_records(seed=0)
        tgt = _synth_records(seed=0, flip=True)
        params = init_mlp_params(MCFG.cost_model, jax.random.PRNGKey(0))
        params, _ = train_cost_model(params, src, MCFG.cost_model, epochs=8)
        frozen_corr = rank_correlation(params, tgt)
        adapter = MosesAdapter(cfg=MCFG, params=jax.tree.map(jnp.copy, params),
                               source_pool=src)
        small = Records(x=tgt.x[:80], y=tgt.y[:80], g=tgt.g[:80])
        adapter.adapt(small, epochs=10)
        adapted_corr = rank_correlation(adapter.params, tgt)
        assert adapted_corr > frozen_corr + 0.2

    def test_mask_fraction_tracks_ratio(self):
        src = _synth_records(seed=0)
        params = init_mlp_params(MCFG.cost_model, jax.random.PRNGKey(0))
        for ratio in (0.1, 0.5):
            cfg = MosesConfig(transferable_ratio=ratio)
            adapter = MosesAdapter(cfg=cfg,
                                   params=jax.tree.map(jnp.copy, params))
            adapter.adapt(Records(x=src.x[:64], y=src.y[:64], g=src.g[:64]),
                          epochs=1)
            fracs = [h["mask_frac"] for h in adapter.history]
            assert abs(np.mean(fracs) - ratio) < 0.05, (ratio, fracs)
