"""End-to-end behaviour tests for the paper's system:
the full Moses pipeline (pretrain -> transfer -> adapt -> tune) must beat the
paper's baselines on CMAT, and the training/serving stack must work."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.autotune.dataset import generate_records, training_task_pool
from repro.autotune.tasks import paper_dnn_tasks
from repro.autotune.tuner import tune
from repro.configs import get_smoke_config
from repro.configs.moses import DEFAULT as MCFG
from repro.core.cost_model import (init_mlp_params, rank_correlation,
                                   train_cost_model)
from repro.core.metrics import cmat, summarize
from repro.models import build_model


@pytest.fixture(scope="module")
def pretrained():
    pool = training_task_pool(include_archs=False)
    src = generate_records(pool, MCFG.source_device, programs_per_task=20,
                           seed=0)
    params = init_mlp_params(MCFG.cost_model, jax.random.PRNGKey(0))
    params, _ = train_cost_model(params, src, MCFG.cost_model, epochs=8)
    return pool, src, params


def test_pretrained_model_ranks_source_device(pretrained):
    pool, src, params = pretrained
    corr = rank_correlation(params, src)
    assert corr > 0.85, corr


def test_transfer_gap_exists(pretrained):
    """The far-transfer target must be harder than the near one (paper §1)."""
    pool, src, params = pretrained
    near = generate_records(pool[:12], "tpu_v5e", programs_per_task=20, seed=5)
    far = generate_records(pool[:12], "tpu_edge", programs_per_task=20, seed=5)
    c_near = rank_correlation(params, near)
    c_far = rank_correlation(params, far)
    assert c_far < c_near, (c_far, c_near)


def test_moses_beats_baselines_on_cmat(pretrained):
    """The paper's headline: Moses wins CMAT over Tenset-Finetune on the
    far-transfer device (Table 1)."""
    pool, src, params = pretrained
    tasks = paper_dnn_tasks("squeezenet")[:5]
    results = {}
    for strat in ("tenset-pretrain", "tenset-finetune", "moses"):
        results[strat] = tune(tasks, "tpu_edge", strat, MCFG,
                              trials_per_task=32, pretrained_params=params,
                              source_pool=src, seed=1)
    s = summarize(results, "tenset-finetune")
    assert s["moses"]["cmat_vs_ref"] > 20.0, s
    assert s["moses"]["cmat_vs_ref"] > s["tenset-pretrain"]["cmat_vs_ref"]
    # AC early termination => fewer on-device measurements
    assert (results["moses"].total_measurements
            < results["tenset-finetune"].total_measurements)


def test_moses_search_faster_than_finetune(pretrained):
    pool, src, params = pretrained
    tasks = paper_dnn_tasks("bert-base")[:3]
    r_ft = tune(tasks, "tpu_edge", "tenset-finetune", MCFG,
                trials_per_task=32, pretrained_params=params, seed=2)
    r_mo = tune(tasks, "tpu_edge", "moses", MCFG, trials_per_task=32,
                pretrained_params=params, source_pool=src, seed=2)
    assert r_mo.total_search_seconds < r_ft.total_search_seconds


def test_tuned_configs_beat_default(pretrained):
    """Auto-tuning must beat the vendor-default 'raw' baseline end-to-end."""
    pool, src, params = pretrained
    tasks = paper_dnn_tasks("resnet18")[:4]
    r_raw = tune(tasks, "tpu_v5e", "raw", MCFG, trials_per_task=0)
    r_mo = tune(tasks, "tpu_v5e", "moses", MCFG, trials_per_task=32,
                pretrained_params=params, source_pool=src, seed=3)
    assert r_mo.model_latency < r_raw.model_latency


def test_registry_roundtrip_feeds_kernels(pretrained, tmp_path):
    from repro.autotune.registry import Registry
    pool, src, params = pretrained
    tasks = paper_dnn_tasks("bert-base")[:2]
    r = tune(tasks, "tpu_v5e", "moses", MCFG, trials_per_task=16,
             pretrained_params=params, source_pool=src, seed=4)
    reg = Registry(path=str(tmp_path / "tuned.json"))
    reg.ingest(r)
    reg.save()
    reg2 = Registry(path=str(tmp_path / "tuned.json"))
    cfg = reg2.get("tpu_v5e", tasks[0])
    assert "block_m" in cfg.as_dict()


def test_end_to_end_training_learns():
    """Tiny end-to-end run: loss decreases on the structured stream."""
    from repro.train.data import DataConfig, data_iterator
    from repro.train.optimizer import AdamW, AdamWConfig, cosine_schedule
    from repro.train.train_loop import LoopConfig, run_training
    import tempfile

    cfg = get_smoke_config("h2o-danube-1.8b")
    model = build_model(cfg)
    mesh = jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    opt = AdamW(AdamWConfig(lr=cosine_schedule(3e-3, 5, 40)))
    it = data_iterator(cfg, DataConfig(batch_size=8, seq_len=32, seed=0))
    with tempfile.TemporaryDirectory() as d:
        loop = LoopConfig(total_steps=40, checkpoint_every=40,
                          checkpoint_dir=d, log_every=1000,
                          async_checkpoint=False)
        _, hist = run_training(model, opt, mesh, it, loop,
                               rng=jax.random.PRNGKey(0),
                               log_fn=lambda s: None)
    assert hist[-1]["loss"] < hist[0]["loss"] - 0.5


def test_serving_engine_greedy_deterministic():
    from repro.serve import Engine, Request
    cfg = get_smoke_config("xlstm-350m")
    model = build_model(cfg)
    mesh = jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.RandomState(0)
    prompts = [rng.randint(0, cfg.vocab_size, size=8).astype(np.int32)
               for _ in range(3)]

    def gen():
        eng = Engine(model, params, mesh, max_len=32, batch_slots=2)
        reqs = [Request(prompt=p, max_new_tokens=6) for p in prompts]
        eng.generate(reqs)
        return [r.out_tokens for r in reqs]

    a, b = gen(), gen()
    assert a == b
    assert all(len(t) == 6 for t in a)


def test_roofline_collective_parser():
    from repro.launch.roofline import collective_bytes
    hlo = '''
  %ar = f32[1024,512]{1,0} all-reduce(f32[1024,512]{1,0} %x), replica_groups={}
  %ag = bf16[8,128]{1,0} all-gather(bf16[4,128]{1,0} %y), dimensions={0}
  %rs = f32[256]{0} reduce-scatter(f32[2048]{0} %z), dimensions={0}
  %cp = (f32[64]{0}, f32[64]{0}) collective-permute(f32[64]{0} %w), source_target_pairs={{0,1}}
  %other = f32[10]{0} add(f32[10]{0} %a, f32[10]{0} %b)
'''
    out = collective_bytes(hlo)
    assert out["all-reduce_bytes"] == 1024 * 512 * 4 * 2  # ring 2x
    assert out["all-gather_bytes"] == 8 * 128 * 2
    assert out["reduce-scatter_bytes"] == 256 * 4
    assert out["collective-permute_bytes"] == 64 * 4 * 2  # tuple result
    assert out["total_bytes"] > 0
