"""Training substrate: optimizer, data determinism, checkpoint/restart
(including simulated node failure + bitwise continuation), elastic reshard."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import build_model
from repro.train.checkpoint import CheckpointManager
from repro.train.data import DataConfig, data_iterator
from repro.train.optimizer import (AdamW, AdamWConfig, cosine_schedule,
                                   global_norm)
from repro.train.train_loop import (LoopConfig, init_train_state,
                                    make_train_step, run_training)


class TestOptimizer:
    def test_adamw_reduces_quadratic(self):
        opt = AdamW(AdamWConfig(lr=0.1))
        params = {"w": jnp.array([5.0, -3.0])}
        state = opt.init(params)
        for _ in range(200):
            grads = {"w": 2 * params["w"]}
            params, state, _ = opt.update(grads, state, params)
        assert float(jnp.abs(params["w"]).max()) < 1e-2

    def test_grad_clipping(self):
        opt = AdamW(AdamWConfig(lr=1e-3, grad_clip_norm=1.0))
        params = {"w": jnp.zeros(4)}
        state = opt.init(params)
        _, _, m = opt.update({"w": jnp.full(4, 100.0)}, state, params)
        assert float(m["grad_norm"]) == pytest.approx(200.0)

    def test_master_fp32_with_bf16_params(self):
        opt = AdamW(AdamWConfig(lr=0.05, master_fp32=True,
                                moment_dtype="bfloat16"))
        params = {"w": jnp.ones(8, jnp.bfloat16)}
        state = opt.init(params)
        assert state["master"]["w"].dtype == jnp.float32
        assert state["m"]["w"].dtype == jnp.bfloat16
        for _ in range(5):
            params, state, _ = opt.update({"w": jnp.ones(8)}, state, params)
        assert params["w"].dtype == jnp.bfloat16
        # master tracks higher-precision value
        assert float(state["master"]["w"][0]) < 1.0

    def test_cosine_schedule_shape(self):
        lr = cosine_schedule(1e-3, 10, 100)
        assert float(lr(jnp.asarray(0))) == 0.0
        assert float(lr(jnp.asarray(10))) == pytest.approx(1e-3)
        assert float(lr(jnp.asarray(100))) == pytest.approx(1e-4, rel=0.1)


class TestData:
    def test_deterministic_across_runs(self):
        cfg = get_smoke_config("h2o-danube-1.8b")
        it1 = data_iterator(cfg, DataConfig(batch_size=4, seq_len=16, seed=7))
        it2 = data_iterator(cfg, DataConfig(batch_size=4, seq_len=16, seed=7))
        for _ in range(3):
            b1, b2 = next(it1), next(it2)
            np.testing.assert_array_equal(b1["tokens"], b2["tokens"])

    def test_hosts_get_disjoint_streams(self):
        cfg = get_smoke_config("h2o-danube-1.8b")
        a = next(data_iterator(cfg, DataConfig(seed=7, host_id=0,
                                               num_hosts=2)))
        b = next(data_iterator(cfg, DataConfig(seed=7, host_id=1,
                                               num_hosts=2)))
        assert not np.array_equal(a["tokens"], b["tokens"])

    def test_targets_are_shifted_tokens(self):
        cfg = get_smoke_config("xlstm-350m")
        b = next(data_iterator(cfg, DataConfig(batch_size=2, seq_len=16)))
        assert b["tokens"].shape == b["targets"].shape
        # markov structure: targets[t] is the stream successor of tokens[t]
        assert not np.array_equal(b["tokens"], b["targets"])

    def test_frontend_stubs_provided(self):
        for arch in ("whisper-tiny", "llama-3.2-vision-90b"):
            cfg = get_smoke_config(arch)
            b = next(data_iterator(cfg, DataConfig(batch_size=2, seq_len=8)))
            key = ("encoder_embeddings" if cfg.is_encoder_decoder
                   else "frontend_embeddings")
            assert key in b


class TestCheckpoint:
    def test_save_restore_roundtrip(self, tmp_path):
        ckpt = CheckpointManager(str(tmp_path), keep_n=2)
        tree = {"a": jnp.arange(6).reshape(2, 3),
                "b": {"c": jnp.ones(4, jnp.bfloat16)}}
        ckpt.save(10, tree)
        like = jax.tree.map(lambda x: jnp.zeros_like(x), tree)
        out = ckpt.restore(10, like)
        np.testing.assert_array_equal(np.asarray(out["a"]),
                                      np.asarray(tree["a"]))
        assert out["b"]["c"].dtype == jnp.bfloat16

    def test_keep_n_garbage_collection(self, tmp_path):
        ckpt = CheckpointManager(str(tmp_path), keep_n=2)
        t = {"a": jnp.zeros(2)}
        for s in (1, 2, 3, 4):
            ckpt.save(s, t)
        assert ckpt.all_steps() == [3, 4]

    def test_atomic_no_partial_dirs(self, tmp_path):
        ckpt = CheckpointManager(str(tmp_path), keep_n=5)
        ckpt.save(1, {"a": jnp.zeros(2)})
        assert not [d for d in os.listdir(tmp_path) if d.endswith(".tmp")]

    def test_async_save(self, tmp_path):
        ckpt = CheckpointManager(str(tmp_path), keep_n=2, async_save=True)
        ckpt.save(5, {"a": jnp.arange(3)})
        ckpt.wait()
        assert ckpt.latest_step() == 5


class TestFaultTolerance:
    def _setup(self, tmp_path, total=30):
        cfg = get_smoke_config("xlstm-350m").replace(num_layers=2)
        model = build_model(cfg)
        mesh = jax.make_mesh((1, 1), ("data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,) * 2)
        opt = AdamW(AdamWConfig(lr=1e-3))
        loop = LoopConfig(total_steps=total, checkpoint_every=10,
                          checkpoint_dir=str(tmp_path), log_every=1000,
                          async_checkpoint=False)
        return cfg, model, mesh, opt, loop

    def test_failure_restart_continues_identically(self, tmp_path):
        cfg, model, mesh, opt, loop = self._setup(tmp_path)

        def data():
            return data_iterator(cfg, DataConfig(batch_size=2, seq_len=16,
                                                 seed=3))

        # uninterrupted run
        _, hist_full = run_training(model, opt, mesh, data(), loop,
                                    rng=jax.random.PRNGKey(0),
                                    log_fn=lambda s: None)

        # interrupted at step 15 -> restart from checkpoint at step 10
        loop2 = LoopConfig(total_steps=30, checkpoint_every=10,
                           checkpoint_dir=str(tmp_path) + "_b",
                           log_every=1000, async_checkpoint=False)
        with pytest.raises(RuntimeError, match="simulated node failure"):
            run_training(model, opt, mesh, data(), loop2,
                         rng=jax.random.PRNGKey(0), fail_at_step=15,
                         log_fn=lambda s: None)
        # restart: data replays from the batch at the restored step
        it = data()
        for _ in range(10):
            next(it)
        _, hist_resumed = run_training(model, opt, mesh, it, loop2,
                                       log_fn=lambda s: None)
        # identical final loss as the uninterrupted run
        assert hist_resumed[-1]["step"] == 30
        assert hist_resumed[-1]["loss"] == pytest.approx(
            hist_full[-1]["loss"], rel=1e-5)

    def test_elastic_restore_to_different_mesh(self, tmp_path):
        """Checkpoint written under one sharding restores onto another mesh
        (here 1-device mesh with different logical shape) bit-identically."""
        cfg, model, mesh, opt, loop = self._setup(tmp_path, total=10)
        data = data_iterator(cfg, DataConfig(batch_size=2, seq_len=16, seed=3))
        state, _ = run_training(model, opt, mesh, data, loop,
                                rng=jax.random.PRNGKey(0),
                                log_fn=lambda s: None)
        ckpt = CheckpointManager(str(tmp_path))
        step = ckpt.latest_step()
        from repro.train.train_loop import train_state_shardings
        mesh2 = jax.make_mesh((1,), ("model",),
                              axis_types=(jax.sharding.AxisType.Auto,))
        like = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
        shardings2, _, _ = train_state_shardings(model, opt, mesh2)
        restored = ckpt.restore(step, like, shardings2)
        np.testing.assert_array_equal(
            np.asarray(restored["params"]["embed"]),
            np.asarray(state["params"]["embed"]))


class TestCompression:
    def test_int8_error_feedback_converges(self):
        from repro.distributed.compression import \
            simulate_compressed_allreduce
        rng = np.random.RandomState(0)
        shards = [jnp.asarray(rng.randn(64).astype(np.float32))
                  for _ in range(4)]
        exact = np.mean([np.asarray(s) for s in shards], axis=0)
        errors = [jnp.zeros(64) for _ in range(4)]
        # with error feedback the *accumulated* mean over steps converges
        acc_comp = np.zeros(64)
        acc_exact = np.zeros(64)
        for step in range(50):
            mean, errors = simulate_compressed_allreduce(shards, errors)
            acc_comp += np.asarray(mean)
            acc_exact += exact
        rel = np.abs(acc_comp - acc_exact).max() / np.abs(acc_exact).max()
        assert rel < 5e-3, rel

    def test_single_step_quantization_bounded(self):
        from repro.distributed.compression import \
            simulate_compressed_allreduce
        rng = np.random.RandomState(0)
        shards = [jnp.asarray(rng.randn(128).astype(np.float32))
                  for _ in range(8)]
        errors = [jnp.zeros(128)] * 8
        mean, _ = simulate_compressed_allreduce(shards, errors)
        exact = np.mean([np.asarray(s) for s in shards], axis=0)
        scale = max(float(np.abs(np.asarray(s)).max()) for s in shards) / 127
        assert np.abs(np.asarray(mean) - exact).max() <= scale * 1.01
