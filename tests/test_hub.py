"""Transfer Hub tests: record-store persistence invariants, fingerprint
determinism (in- and cross-process), source-selection ranking sanity,
TuningHub serving semantics (hit / miss / in-flight dedup / batching), and
the registry atomicity + locking satellites.

The end-to-end acceptance path lives in TestTuningHub.test_unseen_device_e2e:
a device absent from the store is fingerprinted, Moses warm-starts from the
auto-selected nearest source, and the second get_config for the same
(device, workload) is a registry hit with zero new measurements.
"""
import dataclasses
import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.autotune.space import ProgramConfig, Workload, default_config
from repro.configs.moses import DEFAULT as MCFG
from repro.hub import (RecordStore, StoreSchemaError, TuningHub,
                       bootstrap_store, device_fingerprint,
                       fingerprint_similarity, probe_suite, select_sources)
from repro.hub.store import SCHEMA_VERSION

WL_A = Workload("matmul", (256, 256, 128), name="a")
WL_B = Workload("matmul", (512, 256, 128), name="b")
CFG_A = default_config(WL_A)
CFG_A2 = ProgramConfig.make(block_m=64, block_n=128, block_k=128,
                            k_inner=0, unroll=1, out_bf16=1)

TINY_CFG = dataclasses.replace(
    MCFG, online_epochs=2, adaptation_epochs=2, population_size=32,
    evolution_rounds=2, top_k_measure=8)


def _boot(store, devices=("tpu_v5e", "tpu_edge"), n=8):
    return bootstrap_store(store, devices, [WL_A, WL_B],
                           programs_per_task=n)


class TestRecordStore:
    def test_round_trip(self, tmp_path):
        store = RecordStore(str(tmp_path / "s"))
        assert store.put("tpu_v5e", WL_A, CFG_A, 100.0)
        assert store.put("tpu_v5e", WL_A, CFG_A2, 50.0)
        assert store.put("tpu_v5e", WL_B, CFG_A, 75.0)
        assert store.flush() == 3
        loaded = RecordStore(str(tmp_path / "s"))
        assert loaded.devices() == ["tpu_v5e"]
        assert loaded.count("tpu_v5e") == 3
        assert loaded.task_keys("tpu_v5e") == sorted(
            [WL_A.key(), WL_B.key()])
        recs = loaded.records("tpu_v5e")
        assert len(recs) == 3
        assert recs.x.shape[1] == 164
        assert sorted(recs.raw_throughput.tolist()) == [50.0, 75.0, 100.0]
        # per-task normalization: each task group's best record is 1.0
        for g in np.unique(recs.g):
            assert recs.y[recs.g == g].max() == pytest.approx(1.0)

    def test_dedup_within_and_across_flushes(self, tmp_path):
        store = RecordStore(str(tmp_path / "s"))
        assert store.put("tpu_v5e", WL_A, CFG_A, 100.0)
        assert not store.put("tpu_v5e", WL_A, CFG_A, 101.0)  # same point
        assert store.put("tpu_v5e", WL_A, CFG_A, 99.0, trial=1)  # new trial
        store.flush()
        # a fresh instance re-reads the shard index: still deduped
        again = RecordStore(str(tmp_path / "s"))
        assert not again.put("tpu_v5e", WL_A, CFG_A, 102.0)
        assert again.count("tpu_v5e") == 2

    def test_schema_version_rejected(self, tmp_path):
        store = RecordStore(str(tmp_path / "s"))
        store.put("tpu_v5e", WL_A, CFG_A, 100.0)
        store.flush()
        shard = next(
            os.path.join(r, f)
            for r, _, fs in os.walk(tmp_path / "s" / "records")
            for f in fs if f.endswith(".jsonl"))
        with open(shard) as f:
            rec = json.loads(f.readline())
        rec["schema"] = SCHEMA_VERSION + 1
        with open(shard, "a") as f:
            f.write(json.dumps(rec) + "\n")
        fresh = RecordStore(str(tmp_path / "s"))
        with pytest.raises(StoreSchemaError):
            list(fresh.iter_device("tpu_v5e"))

    def test_torn_trailing_line_tolerated(self, tmp_path):
        store = RecordStore(str(tmp_path / "s"))
        store.put("tpu_v5e", WL_A, CFG_A, 100.0)
        store.flush()
        shard = next(
            os.path.join(r, f)
            for r, _, fs in os.walk(tmp_path / "s" / "records")
            for f in fs if f.endswith(".jsonl"))
        with open(shard, "a") as f:
            f.write('{"schema": 1, "knobs": {"trunc')  # killed writer
        assert RecordStore(str(tmp_path / "s")).count("tpu_v5e") == 1

    def test_crashed_flush_preserves_existing_shard(self, tmp_path,
                                                    monkeypatch):
        root = str(tmp_path / "s")
        store = RecordStore(root)
        store.put("tpu_v5e", WL_A, CFG_A, 100.0)
        store.flush()

        def boom(*a, **k):
            raise OSError("disk died mid-rename")

        crashy = RecordStore(root)
        crashy.put("tpu_v5e", WL_A, CFG_A2, 50.0)
        monkeypatch.setattr("repro.hub.store.os.replace", boom)
        with pytest.raises(OSError):
            crashy.flush()
        monkeypatch.undo()
        assert RecordStore(root).count("tpu_v5e") == 1  # original intact

    def test_model_params_roundtrip_and_family_check(self, tmp_path):
        store = RecordStore(str(tmp_path / "s"))
        params = {"w0": np.ones((3, 2), np.float32),
                  "b0": np.zeros((2,), np.float32)}
        store.save_model_params("tpu_v5e", params, "mlp")
        out = store.load_model_params("tpu_v5e", model_name="mlp")
        np.testing.assert_array_equal(np.asarray(out["w0"]), params["w0"])
        # wrong family -> treated as absent
        assert store.load_model_params("tpu_v5e",
                                       model_name="residual-mlp") is None
        assert store.load_model_params("tpu_edge") is None

    def test_fingerprint_persistence(self, tmp_path):
        store = RecordStore(str(tmp_path / "s"))
        fp = device_fingerprint("tpu_v5e")
        store.put_fingerprint("tpu_v5e", fp)
        np.testing.assert_allclose(
            RecordStore(str(tmp_path / "s")).get_fingerprint("tpu_v5e"), fp)

    def test_stale_probe_version_invalidates_fingerprints(self, tmp_path):
        store = RecordStore(str(tmp_path / "s"))
        store.put_fingerprint("tpu_v5e", device_fingerprint("tpu_v5e"))
        path = store._fingerprint_path()
        with open(path) as f:
            data = json.load(f)
        data["probe_version"] = data.get("probe_version", 1) + 1
        with open(path, "w") as f:
            json.dump(data, f)
        # written under a different probe suite -> treated as absent
        assert RecordStore(str(tmp_path / "s")).fingerprints() == {}


class TestFingerprint:
    def test_suite_shape(self):
        suite = probe_suite()
        assert len(suite) == 16
        fp = device_fingerprint("tpu_v5e")
        assert fp.shape == (16,)
        assert np.linalg.norm(fp) == pytest.approx(1.0, abs=1e-5)

    def test_deterministic_in_process(self):
        np.testing.assert_array_equal(device_fingerprint("tpu_edge"),
                                      device_fingerprint("tpu_edge"))

    def test_deterministic_across_processes(self):
        code = ("from repro.hub.fingerprint import device_fingerprint;"
                "import json;"
                "print(json.dumps(device_fingerprint('tpu_v5e')"
                ".astype(float).tolist()))")
        env = dict(os.environ)
        src = os.path.join(os.path.dirname(__file__), "..", "src")
        env["PYTHONPATH"] = os.path.abspath(src)
        env.setdefault("JAX_PLATFORMS", "cpu")
        out = subprocess.run([sys.executable, "-c", code], env=env,
                             capture_output=True, text=True, check=True)
        other = np.asarray(json.loads(out.stdout), np.float32)
        np.testing.assert_array_equal(device_fingerprint("tpu_v5e"), other)

    def test_near_clone_more_similar_than_dissimilar(self):
        fp_t = device_fingerprint("tpu_v5e")
        sim_clone = fingerprint_similarity(fp_t,
                                           device_fingerprint("tpu_v5e_pro"))
        sim_edge = fingerprint_similarity(fp_t,
                                          device_fingerprint("tpu_edge"))
        assert sim_clone > 0.99
        assert sim_clone > sim_edge + 0.1


class TestSourceSelection:
    def test_ranking_prefers_near_clone(self, tmp_path):
        store = RecordStore(str(tmp_path / "s"))
        _boot(store, n=4)
        sel = select_sources(store, "tpu_v5e_pro", top_k=2)
        assert [d for d, _ in sel.ranked] == ["tpu_v5e", "tpu_edge"]
        assert sel.best_source == "tpu_v5e"
        weights = dict(sel.sources)
        assert sum(weights.values()) == pytest.approx(1.0)
        assert weights["tpu_v5e"] > weights["tpu_edge"]
        # mixed pool keeps per-(device, task) groups disjoint
        assert sel.pool is not None
        assert len(np.unique(sel.pool.g)) == 4  # 2 tasks x 2 sources

    def test_target_never_its_own_source(self, tmp_path):
        store = RecordStore(str(tmp_path / "s"))
        _boot(store, devices=("tpu_v5e",), n=4)
        sel = select_sources(store, "tpu_v5e")
        assert sel.sources == [] or "tpu_v5e" not in [d for d, _ in
                                                      sel.sources]

    def test_empty_store(self, tmp_path):
        sel = select_sources(RecordStore(str(tmp_path / "s")), "tpu_v5e")
        assert sel.sources == [] and sel.pool is None
        assert sel.pretrained_params is None

    def test_bootstrap_is_idempotent(self, tmp_path):
        store = RecordStore(str(tmp_path / "s"))
        n1 = _boot(store, n=4)
        assert n1 > 0
        assert _boot(store, n=4) == 0


class TestTuningHub:
    def _hub(self, tmp_path, boot=True):
        hub = TuningHub(str(tmp_path / "hub"), moses_cfg=TINY_CFG,
                        trials_per_task=16, pretrain_epochs=2)
        if boot:
            _boot(hub.store)
        return hub

    def test_unseen_device_e2e(self, tmp_path):
        """Acceptance: fingerprint an unseen device, warm-start Moses from
        the auto-selected nearest source, then serve the second query from
        the registry with zero new measurements."""
        hub = self._hub(tmp_path)
        target = "tpu_v5e_pro"
        assert target not in hub.store.devices()

        r1 = hub.get_config(target, WL_A)
        assert not r1.cache_hit
        assert r1.new_measurements > 0
        sel = hub.selection(target)
        assert sel is not None and sel.best_source == "tpu_v5e"
        assert sel.pretrained_params is not None
        assert hub.store.get_fingerprint(target) is not None
        # winners persisted + all measurements written back into the store
        assert os.path.exists(hub.registry.path)
        assert hub.store.count(target) > 0

        r2 = hub.get_config(target, WL_A)
        assert r2.cache_hit
        assert r2.new_measurements == 0
        assert r2.config.knobs == r1.config.knobs
        assert hub.stats.hits == 1 and hub.stats.misses == 1

    def test_request_dedup_and_batched_flush(self, tmp_path):
        hub = self._hub(tmp_path)
        assert hub.request("tpu_v5e_pro", WL_A)
        assert not hub.request("tpu_v5e_pro", WL_A)   # in-flight dedup
        assert hub.stats.dedup_skips == 1
        assert hub.request("tpu_v5e_pro", WL_B)
        assert hub.pending("tpu_v5e_pro") == 2
        results = hub.flush()
        assert len(results) == 1 and hub.stats.jobs == 1  # ONE batched job
        assert len(results[0].tasks) == 2
        assert hub.pending() == 0
        # both workloads now served from the registry
        assert hub.get_config("tpu_v5e_pro", WL_A).cache_hit
        assert hub.get_config("tpu_v5e_pro", WL_B).cache_hit
        # a request for a served workload is refused without queueing
        assert not hub.request("tpu_v5e_pro", WL_A)

    def test_cold_universe_falls_back_to_online_baseline(self, tmp_path):
        hub = self._hub(tmp_path, boot=False)   # empty store: nothing to
        r = hub.get_config("tpu_v5e", WL_A)     # transfer from
        assert not r.cache_hit and r.new_measurements > 0
        assert hub.get_config("tpu_v5e", WL_A).cache_hit

    def test_cold_universe_fallback_any_pretrained_strategy(self, tmp_path):
        # any strategy that requires pretrained params degrades gracefully
        # on an empty store, not just the literal "moses" name
        hub = TuningHub(str(tmp_path / "hub"), moses_cfg=TINY_CFG,
                        trials_per_task=16, strategy="tenset-finetune")
        r = hub.get_config("tpu_v5e", WL_A)
        assert not r.cache_hit and r.new_measurements > 0

    def test_concurrent_inflight_dedup(self, tmp_path):
        import threading
        hub = self._hub(tmp_path)
        target = "tpu_v5e_pro"
        first = {}

        def serve():
            first["r"] = hub.get_config(target, WL_A)

        t = threading.Thread(target=serve)
        t.start()
        # wait until the first call's job is actually in flight
        for _ in range(600):
            with hub._lock:
                if (target, WL_A.key()) in hub._inflight:
                    break
            import time
            time.sleep(0.05)
        else:
            t.join()
            pytest.skip("job finished before in-flight state was observed")
        # second caller: deduped against the in-flight key, blocks on the
        # device job lock, then serves the first job's winner with zero
        # measurements attributed to it
        r2 = hub.get_config(target, WL_A)
        t.join()
        assert r2.new_measurements == 0
        assert r2.config.knobs == first["r"].config.knobs
        assert hub.stats.dedup_skips >= 1
        assert hub.stats.jobs == 1

    def test_prefetch_without_flush(self, tmp_path):
        hub = self._hub(tmp_path)
        r = hub.get_config("tpu_v5e_pro", WL_A, flush=False)
        assert not r.cache_hit
        assert r.new_measurements == 0
        assert r.config.knobs == default_config(WL_A).knobs
        assert hub.pending("tpu_v5e_pro") == 1


class TestRegistrySatellites:
    def _reg(self, path):
        from repro.autotune.registry import Registry
        return Registry(path=path)

    def test_lookup_distinguishes_miss_from_default(self, tmp_path):
        reg = self._reg(str(tmp_path / "r.json"))
        assert reg.lookup("tpu_v5e", WL_A) is None
        assert reg.get("tpu_v5e", WL_A).knobs == default_config(WL_A).knobs
        reg.put("tpu_v5e", WL_A, CFG_A, 123.0)
        entry = reg.lookup("tpu_v5e", WL_A)
        assert entry is not None
        assert entry["throughput_gflops"] == 123.0

    def test_crashed_save_never_corrupts_existing_file(self, tmp_path,
                                                       monkeypatch):
        path = str(tmp_path / "r.json")
        reg = self._reg(path)
        reg.put("tpu_v5e", WL_A, CFG_A, 100.0)
        reg.save()

        # crash INSIDE serialization: the destination file must survive
        def boom(*a, **k):
            raise RuntimeError("killed mid-write")

        reg.put("tpu_v5e", WL_B, CFG_A, 50.0)
        monkeypatch.setattr("repro.autotune.registry.json.dump", boom)
        with pytest.raises(RuntimeError):
            reg.save()
        monkeypatch.undo()
        survivor = self._reg(path)
        assert survivor.lookup("tpu_v5e", WL_A) is not None
        assert survivor.get("tpu_v5e", WL_A).knobs == CFG_A.knobs


class TestFlushDeterminism:
    """flush() must drain identically regardless of request arrival order:
    devices sort lexically, tasks within a device sort by workload key
    (task order feeds the tuner's shared RNG stream, so a drain-order
    change would silently change every result)."""

    WL_C = Workload("matmul", (128, 128, 128), name="c")

    def _capture_hub(self, tmp_path, name):
        hub = TuningHub(str(tmp_path / name), moses_cfg=TINY_CFG,
                        trials_per_task=8)
        calls = []

        def fake_tune_batch(device, tasks):
            calls.append((device, [wl.key() for wl in tasks]))

            class _R:
                total_measurements = 0
                tasks = []
            return _R()

        hub._tune_batch = fake_tune_batch
        return hub, calls

    def test_drain_order_independent_of_request_order(self, tmp_path):
        orders = [
            [("tpu_v5e", WL_B), ("tpu_edge", WL_A), ("tpu_v5e", WL_A),
             ("tpu_edge", self.WL_C), ("tpu_v5e", self.WL_C)],
            [("tpu_v5e", self.WL_C), ("tpu_edge", self.WL_C),
             ("tpu_v5e", WL_A), ("tpu_v5e", WL_B), ("tpu_edge", WL_A)],
        ]
        drains = []
        for i, reqs in enumerate(orders):
            hub, calls = self._capture_hub(tmp_path, f"h{i}")
            for dev, wl in reqs:
                assert hub.request(dev, wl)
            hub.flush()
            drains.append(calls)
            assert hub.pending() == 0
        assert drains[0] == drains[1]
        # devices drain in sorted order; tasks sorted by key within each
        assert [d for d, _ in drains[0]] == ["tpu_edge", "tpu_v5e"]
        for _, keys in drains[0]:
            assert keys == sorted(keys)

    def test_single_device_flush_sorts_tasks(self, tmp_path):
        hub, calls = self._capture_hub(tmp_path, "h")
        for wl in (WL_B, self.WL_C, WL_A):
            hub.request("tpu_lite", wl)
        hub.flush("tpu_lite")
        (dev, keys), = calls
        assert dev == "tpu_lite" and keys == sorted(keys)

    def test_pending_by_device_and_inflight_surface(self, tmp_path):
        hub, _ = self._capture_hub(tmp_path, "h")
        hub.request("tpu_v5e", WL_A)
        hub.request("tpu_v5e", WL_B)
        hub.request("tpu_edge", WL_A)
        assert hub.pending_by_device() == {"tpu_edge": 1, "tpu_v5e": 2}
        assert hub.pending() == 3
        assert hub.inflight() == 0
        hub.flush()
        assert hub.pending_by_device() == {}


class TestPoisonedRecords:
    """Satellite (ISSUE 6): poisoned measurements flow executor -> TaskResult
    -> store error records -> HubStats, without ever contaminating the
    training corpus."""

    def test_store_error_records_coexist_and_stay_out_of_training(
            self, tmp_path):
        store = RecordStore(str(tmp_path / "s"))
        assert store.put("tpu_v5e", WL_A, CFG_A, 100.0, trial=0)
        assert store.put("tpu_v5e", WL_A, CFG_A2, None, trial=0,
                         error="worker process died")
        # an error record and a good one of the SAME identity are distinct
        # facts: the config crashed once and later measured fine
        assert store.put("tpu_v5e", WL_A, CFG_A2, 50.0, trial=0)
        assert not store.put("tpu_v5e", WL_A, CFG_A2, None, trial=0,
                             error="worker process died")   # dedup
        assert store.flush() == 3
        loaded = RecordStore(str(tmp_path / "s"))
        # training reads never see the poisoned row
        assert loaded.count("tpu_v5e") == 2
        recs = loaded.records("tpu_v5e")
        assert sorted(recs.raw_throughput.tolist()) == [50.0, 100.0]
        # diagnostics do
        assert loaded.count("tpu_v5e", include_errors=True) == 3
        errs = loaded.error_records("tpu_v5e")
        assert len(errs) == 1
        assert errs[0]["error"] == "worker process died"
        assert errs[0]["throughput_gflops"] is None

    def test_flush_with_poisoned_configs(self, tmp_path):
        """An executor injecting crashes during a gradient-scheduled hub job:
        winners still land in the Registry, poisoned measurements are
        persisted with `error` set, and HubStats counts them."""
        from repro.autotune.devices import FaultInjector
        from repro.sched import MeasurementExecutor
        fi = FaultInjector(crash=0.10, seed=13)
        with MeasurementExecutor(workers=2, retries=0, measure_fn=fi) as ex:
            hub = TuningHub(str(tmp_path / "hub"), moses_cfg=TINY_CFG,
                            trials_per_task=16, pretrain_epochs=2,
                            scheduler="gradient", executor=ex)
            _boot(hub.store)
            target = "tpu_v5e_pro"
            hub.request(target, WL_A)
            hub.request(target, WL_B)
            results = hub.flush()
        assert len(results) == 1
        # winners served despite the hostile candidates
        assert hub.registry.lookup(target, WL_A) is not None
        assert hub.registry.lookup(target, WL_B) is not None
        assert hub.stats.measurements > 0
        assert hub.stats.poisoned > 0, \
            "fault map never fired during the job; reseed the injector"
        errs = hub.store.error_records(target)
        assert len(errs) == hub.stats.poisoned
        assert all(e["error"] and e["throughput_gflops"] is None
                   for e in errs)
        # the poisoned rows are already persisted (flush ran) and excluded
        # from the device's training corpus
        persisted = RecordStore(os.path.join(str(tmp_path / "hub"), "store"))
        assert len(persisted.error_records(target)) == len(errs)
        assert persisted.count(target) == hub.stats.measurements

    def test_executor_requires_gradient_scheduler(self, tmp_path):
        with pytest.raises(ValueError, match="gradient"):
            TuningHub(str(tmp_path / "hub"), executor="process")
