"""Tuning Scheduler subsystem: executor, draft-then-verify, campaign engine.

Covers the three sched/ pieces plus the satellites that feed them:
`derive_job_seed` cross-process golden stability (scheduler replay depends
on it) and `measurement_seconds` monotonicity (the scheduler's cost signal).
"""
import dataclasses
import subprocess
import sys
import threading
import time

import numpy as np
import pytest

from repro.autotune import devices as dev_mod
from repro.autotune.session import TuneSession, derive_job_seed
from repro.autotune.space import (ProgramConfig, Workload, default_config,
                                  random_config)
from repro.configs.moses import DEFAULT as MCFG
from repro.core.cost_model import Records, resolve_cost_model
from repro.sched import (MeasurementExecutor, RidgeDraft, SchedulerConfig,
                         SpecStats, SpeculativeScorer, batch_wall_seconds,
                         run_campaign)

WL = Workload("matmul", (256, 256, 128), name="wl")
TINY_CFG = dataclasses.replace(
    MCFG, online_epochs=2, adaptation_epochs=2, population_size=32,
    evolution_rounds=2, top_k_measure=8)


def _configs(n, seed=0):
    rng = np.random.RandomState(seed)
    out, seen = [], set()
    while len(out) < n:
        c = random_config(WL, rng)
        if c.knobs not in seen:
            seen.add(c.knobs)
            out.append(c)
    return out


# ---------------------------------------------------------------------------
# executor
# ---------------------------------------------------------------------------


class TestExecutor:
    def test_batch_results_in_submission_order(self):
        """Outcomes come back input-ordered and value-identical to a serial
        run, regardless of worker interleaving."""
        cfgs = _configs(24)

        def jittery(wl, cfg, device, trial=0):
            time.sleep((hash(cfg.knobs) % 7) / 1000.0)
            return dev_mod.measure(wl, cfg, device, trial=trial)

        with MeasurementExecutor(workers=8, measure_fn=jittery) as ex:
            outs = ex.measure_batch(WL, cfgs, "tpu_v5e", trial=3)
        assert [o.request.config for o in outs] == cfgs
        assert [o.request.seq for o in outs] == sorted(
            o.request.seq for o in outs)
        serial = [dev_mod.measure(WL, c, "tpu_v5e", trial=3) for c in cfgs]
        assert np.allclose([o.throughput for o in outs], serial)

    def test_poisoned_config_fails_alone(self):
        cfgs = _configs(8)
        bad = cfgs[3]

        def poisoned(wl, cfg, device, trial=0):
            if cfg is bad:
                raise RuntimeError("kernel hang")
            return dev_mod.measure(wl, cfg, device, trial=trial)

        with MeasurementExecutor(workers=3, retries=1,
                                 measure_fn=poisoned) as ex:
            outs = ex.measure_batch(WL, cfgs, "tpu_v5e")
            assert not outs[3].ok and "kernel hang" in outs[3].error
            assert outs[3].attempts == 2          # retried once
            assert outs[3].seconds > 0            # the attempt still cost time
            assert all(o.ok for i, o in enumerate(outs) if i != 3)
            # the pool survives a poisoned config
            outs2 = ex.measure_batch(WL, _configs(4, seed=1), "tpu_v5e")
            assert all(o.ok for o in outs2)

    def test_retry_with_backoff_recovers_transient_failure(self):
        calls = {}
        lock = threading.Lock()

        def flaky(wl, cfg, device, trial=0):
            with lock:
                n = calls[cfg.knobs] = calls.get(cfg.knobs, 0) + 1
            if n == 1:
                raise IOError("transient")
            return dev_mod.measure(wl, cfg, device, trial=trial)

        with MeasurementExecutor(workers=2, retries=2, backoff_s=0.001,
                                 measure_fn=flaky) as ex:
            outs = ex.measure_batch(WL, _configs(6), "tpu_v5e")
        assert all(o.ok and o.attempts == 2 for o in outs)

    def test_timeout_releases_waiter_not_pool(self):
        cfgs = _configs(6)
        slow = cfgs[2]
        release = threading.Event()

        def wedged(wl, cfg, device, trial=0):
            if cfg is slow:
                release.wait(5.0)      # wedged until the test releases it
            return dev_mod.measure(wl, cfg, device, trial=trial)

        with MeasurementExecutor(workers=4, timeout_s=0.2,
                                 measure_fn=wedged) as ex:
            outs = ex.measure_batch(WL, cfgs, "tpu_v5e")
            assert not outs[2].ok and "timeout" in outs[2].error
            # a timeout still pays simulated seconds — a wedged task must
            # not look CHEAP to the scheduler's gain/cost priority
            assert outs[2].seconds > 0
            assert all(o.ok for i, o in enumerate(outs) if i != 2)
            release.set()              # stale result must be dropped...
            outs2 = ex.measure_batch(WL, _configs(4, seed=2), "tpu_v5e")
            assert all(o.ok for o in outs2)   # ...and the pool keeps serving

    def test_bounded_queue_backpressure(self):
        with MeasurementExecutor(workers=1, queue_size=2) as ex:
            outs = ex.measure_batch(WL, _configs(12), "tpu_v5e")
        assert all(o.ok for o in outs)

    def test_submit_after_shutdown_raises(self):
        ex = MeasurementExecutor(workers=1)
        ex.shutdown()
        with pytest.raises(RuntimeError):
            ex.submit(WL, default_config(WL), "tpu_v5e")

    def test_batch_wall_seconds_lpt(self):
        assert batch_wall_seconds([], 4) == 0.0
        assert batch_wall_seconds([3, 1, 1, 1], 2) == 3.0
        assert batch_wall_seconds([2, 2, 2, 2], 4) == 2.0
        # never below the serial-per-worker lower bound or the longest item
        costs = [0.5, 1.5, 0.25, 2.0, 1.0]
        w = batch_wall_seconds(costs, 2)
        assert w >= max(max(costs), sum(costs) / 2)
        assert w <= sum(costs)


# ---------------------------------------------------------------------------
# draft-then-verify
# ---------------------------------------------------------------------------


def _records(n=64, seed=0):
    rng = np.random.RandomState(seed)
    x = rng.rand(n, MCFG.cost_model.feature_dim).astype(np.float32)
    # labels linearly tied to a feature the draft's stride keeps (col 0)
    y = (0.2 + 0.8 * x[:, 0]).astype(np.float32)
    return Records(x=x, y=y, g=np.zeros(n, np.int32))


class TestSpeculative:
    def test_ridge_draft_gates_until_min_rows(self):
        d = RidgeDraft(min_rows=16)
        assert not d.fit(_records(8))
        assert not d.fitted
        assert d.fit(_records(32))
        assert d.fitted

    def test_ridge_draft_learns_linear_signal(self):
        d = RidgeDraft()
        rec = _records(128)
        d.fit(rec)
        pred = d.predict(rec.x)
        assert pred.shape == (128,)
        # rank agreement with the linear label
        rs = np.argsort(np.argsort(pred))
        ry = np.argsort(np.argsort(rec.y))
        assert np.corrcoef(rs, ry)[0, 1] > 0.9

    def _scorer(self, **kw):
        model = resolve_cost_model("mlp", MCFG.cost_model)
        import jax
        params = model.init(jax.random.PRNGKey(0))
        return SpeculativeScorer(model, **kw), model, params

    def test_unfitted_draft_scores_everything_full(self):
        scorer, model, params = self._scorer()
        rec = _records(64)
        out = scorer(params, rec.x)
        assert np.allclose(out, model.batched_predict(params, rec.x))
        assert scorer.stats.unscreened_rows == 64
        assert scorer.stats.full_rows == 0 and scorer.stats.screened == 0

    def test_screened_batch_is_rank_safe(self):
        """Verified rows keep full-model scores; every draft-only row ranks
        strictly below every verified row."""
        scorer, model, params = self._scorer(
            keep_frac=0.25, min_full=8, audit=0, distill=False,
            draft=RidgeDraft())
        rec = _records(128)
        scorer.refit(rec)            # label-supervised refit path
        out = scorer(params, rec.x)
        st = scorer.stats
        assert st.screened == 1
        assert st.full_rows == 32 and st.draft_rows == 128
        full = model.batched_predict(params, rec.x)
        verified = np.argsort(-out)[:32]
        # the winner is the full model's winner among the verified slice
        assert out[verified[0]] == pytest.approx(full[verified].max())
        assert np.allclose(out[verified], full[verified])
        unverified = np.setdiff1d(np.arange(128), verified)
        assert out[unverified].max() < out[verified].min()
        assert 0.0 <= st.acceptance <= 1.0

    def test_audit_rows_join_the_verified_set(self):
        scorer, model, params = self._scorer(
            keep_frac=0.25, min_full=8, audit=8, distill=False,
            draft=RidgeDraft())
        rec = _records(128)
        scorer.refit(rec)
        out = scorer(params, rec.x)
        st = scorer.stats
        assert st.full_rows == 40        # 32 kept + 8 audited
        full = model.batched_predict(params, rec.x)
        verified = np.argsort(-out)[:40]
        assert np.allclose(np.sort(out[verified]), np.sort(full[verified]))

    def test_distillation_fits_draft_from_teacher_scores(self):
        scorer, model, params = self._scorer()     # distill=True default
        assert not scorer.draft.fitted
        rec = _records(128)
        scorer(params, rec.x)            # unscreened, observed by the draft
        assert scorer.draft.fitted
        out2 = scorer(params, _records(128, seed=5).x)
        assert scorer.stats.screened == 1
        assert len(out2) == 128

    def test_small_batches_bypass_screening(self):
        scorer, _, params = self._scorer(keep_frac=0.25, min_full=16)
        scorer.refit(_records(64))
        scorer(params, _records(16, seed=3).x)   # keep >= n: no screening
        assert scorer.stats.screened == 0
        assert scorer.stats.unscreened_rows == 16

    def test_reduction_math(self):
        st = SpecStats(draft_rows=400, full_rows=100, unscreened_rows=100)
        # plain run would score 500 rows; this one scored 200
        assert st.full_model_reduction == pytest.approx(2.5)
        assert SpecStats().full_model_reduction == pytest.approx(0.0)


# ---------------------------------------------------------------------------
# campaign engine + gradient scheduler
# ---------------------------------------------------------------------------


JOBS = [("tpu_v5e", [Workload("matmul", (256, 256, 128), name="a"),
                     Workload("scan", (1024, 512), name="s")]),
        ("tpu_edge", [Workload("matmul", (512, 256, 128), name="b")])]


class TestCampaign:
    @pytest.fixture(scope="class")
    def campaign(self):
        return run_campaign(JOBS, TINY_CFG, strategy="ansor-random",
                            trials_per_task=24, speculative=True)

    def test_results_follow_job_order(self, campaign):
        assert [r.device for r in campaign.results] == ["tpu_v5e", "tpu_edge"]
        assert [t.workload.name for t in campaign.results[0].tasks] == \
            ["a", "s"]
        assert all(t.measurements > 0 for r in campaign.results
                   for t in r.tasks)
        assert all(t.best_latency > 0 for r in campaign.results
                   for t in r.tasks)

    def test_budget_respected(self, campaign):
        # global trial budget (3 tasks x 24) + one confirmation per task
        assert campaign.total_measurements <= 24 * 3 + 3
        assert campaign.spent_seconds == pytest.approx(
            sum(r.total_search_seconds for r in campaign.results))
        # parallel makespan estimate never exceeds serial device time
        assert campaign.wall_seconds <= campaign.spent_seconds + 1e-6

    def test_warmup_then_floor_then_gradient(self, campaign):
        reasons = [t.reason for t in campaign.trace]
        warm = SchedulerConfig().warmup_rounds * 3   # 3 tasks
        assert all(r == "warmup" for r in reasons[:warm])
        assert set(reasons[warm:]) <= {"floor", "gradient"}
        # every task cleared the warmup/floor floor
        per_key = {}
        for t in campaign.trace:
            per_key[t.key] = per_key.get(t.key, 0) + 1
        assert all(v >= SchedulerConfig().min_rounds
                   for v in per_key.values())

    def test_trace_budget_monotonic_and_latency_improves(self, campaign):
        spent = [t.spent_seconds for t in campaign.trace]
        assert spent == sorted(spent)
        ms = [t.measured_seconds for t in campaign.trace]
        assert ms == sorted(ms)
        assert all(m <= s for m, s in zip(ms, spent))
        # NB: no monotone-improvement claim on the latency column — best-by-
        # measured-throughput under noise can wiggle the noiseless latency
        # either way (the serial tuner's convention too, and at tiny budgets
        # an untrained model can even trail the vendor default)
        lats = [t.total_best_latency for t in campaign.trace]
        assert all(np.isfinite(v) and v > 0 for v in lats)
        # the curve is the trace plus the post-finish() closing point
        curve = campaign.curve()
        assert len(curve) == len(campaign.trace) + 1
        assert curve[-1][0] >= campaign.trace[-1].measured_seconds
        assert curve[-1][1] == pytest.approx(sum(
            t.best_latency * t.workload.count
            for r in campaign.results for t in r.tasks))

    def test_campaign_deterministic(self, campaign):
        again = run_campaign(JOBS, TINY_CFG, strategy="ansor-random",
                             trials_per_task=24, speculative=True)
        for r1, r2 in zip(campaign.results, again.results):
            for t1, t2 in zip(r1.tasks, r2.tasks):
                assert t1.best_config.knobs == t2.best_config.knobs
                assert t1.best_latency == t2.best_latency
                assert t1.measurements == t2.measurements
        assert [t.key for t in campaign.trace] == \
            [t.key for t in again.trace]

    def test_speculative_stats_populated(self, campaign):
        st = campaign.spec_stats
        assert st is not None and st.batches > 0
        assert st.full_rows + st.unscreened_rows > 0

    def test_budget_seconds_caps_measurement(self):
        short = run_campaign(JOBS, TINY_CFG, strategy="ansor-random",
                             trials_per_task=24, budget_seconds=5.0)
        full = run_campaign(JOBS, TINY_CFG, strategy="ansor-random",
                            trials_per_task=24)
        assert short.total_measurements < full.total_measurements

    def test_raw_strategy_short_circuits(self):
        res = run_campaign(JOBS, TINY_CFG, strategy="raw",
                           trials_per_task=8)
        assert res.total_measurements == 0
        for r in res.results:
            for t in r.tasks:
                assert t.best_config.knobs == \
                    default_config(t.workload).knobs


class TestRunMany:
    def test_serial_mode_matches_run(self):
        s1 = TuneSession(moses_cfg=TINY_CFG, seed=3, trials_per_task=16)
        r_many = s1.run_many(dict(JOBS), strategy="ansor-random",
                             scheduler="serial")
        s2 = TuneSession(moses_cfg=TINY_CFG, seed=3, trials_per_task=16)
        r_each = [s2.run(tasks, dev, "ansor-random") for dev, tasks in JOBS]
        for a, b in zip(r_many, r_each):
            assert a.device == b.device
            for ta, tb in zip(a.tasks, b.tasks):
                assert ta.best_config.knobs == tb.best_config.knobs

    def test_gradient_mode_ingests_registry_and_results(self, tmp_path):
        from repro.autotune.registry import Registry
        reg = Registry(path=str(tmp_path / "reg.json"))
        session = TuneSession(moses_cfg=TINY_CFG, seed=3, registry=reg,
                              trials_per_task=16)
        results = session.run_many(dict(JOBS), strategy="ansor-random",
                                   scheduler="gradient")
        assert session.results == results
        for r in results:
            for t in r.tasks:
                assert reg.lookup(r.device, t.workload) is not None

    def test_unknown_scheduler_rejected(self):
        session = TuneSession(moses_cfg=TINY_CFG)
        with pytest.raises(ValueError, match="unknown scheduler"):
            session.run_many(dict(JOBS), scheduler="mystery")

    def test_serial_mode_rejects_campaign_only_kwargs(self):
        session = TuneSession(moses_cfg=TINY_CFG)
        with pytest.raises(ValueError, match="serial.*speculative"):
            session.run_many(dict(JOBS), scheduler="serial",
                             speculative=True)
        with pytest.raises(ValueError, match="serial"):
            session.run_many(dict(JOBS), scheduler="serial",
                             budget_seconds=10.0)


class TestSharedStrategyIsolation:
    def test_moses_task_state_roundtrip(self):
        from repro.autotune.strategies import resolve_strategy
        from repro.core.ac import ACState
        strat = resolve_strategy("moses")
        strat.ac_state = ACState(batch_means=(1.0, 2.0), terminated=True)
        snap = strat.task_state()
        strat.begin_task(WL)               # another task resets the state
        assert strat.task_state().terminated is False
        strat.set_task_state(snap)         # swap the first task back in
        assert strat.task_state().terminated is True
        assert strat.task_state().batch_means == (1.0, 2.0)

    def test_unregistered_instance_rejected_across_scopes(self):
        from repro.autotune.strategies import AnsorRandomStrategy

        class Unregistered(AnsorRandomStrategy):
            name = "not-in-registry"

        with pytest.raises(ValueError, match="not in the\n?.*registry"):
            run_campaign(JOBS, TINY_CFG, strategy=Unregistered(),
                         trials_per_task=8)


# ---------------------------------------------------------------------------
# satellites: seed stability + the scheduler's cost signal
# ---------------------------------------------------------------------------


class TestDeriveJobSeedGolden:
    """Scheduler replay keys on derive_job_seed: the values are pinned so a
    platform / Python / hash-seed change can never silently reshuffle every
    campaign's RNG streams."""

    GOLDEN = [
        ((0, "tpu_v5e", "moses", ""), 1973409032),
        ((0, "tpu_edge", "ansor-random", ""), 845742172),
        ((1, "tpu_v5e", "moses", ""), 2006017956),
        ((0, "tpu_v5e", "moses", "matmul:256x256x128"), 1420564465),
        ((7, "tpu_lite", "tenset-finetune", "scan:2048x512|x"), 167936896),
    ]

    def test_golden_values(self):
        for (base, dev, strat, salt), want in self.GOLDEN:
            assert derive_job_seed(base, dev, strat, salt) == want

    def test_stable_across_processes(self):
        """PYTHONHASHSEED randomization must not leak in (md5, not hash())."""
        code = ("from repro.autotune.session import derive_job_seed as d;"
                "print([d(*a) for a in %r])"
                % [a for a, _ in self.GOLDEN])
        out = subprocess.run(
            [sys.executable, "-c", code], capture_output=True, text=True,
            check=True, env={"PYTHONHASHSEED": "31337",
                             "PYTHONPATH": "src", "JAX_PLATFORMS": "cpu"},
            cwd=__import__("os").path.join(__import__("os").path.dirname(
                __file__), ".."))
        assert eval(out.stdout.strip()) == [w for _, w in self.GOLDEN]

    def test_range_and_distinctness(self):
        seeds = {derive_job_seed(0, d, s)
                 for d in dev_mod.DEVICES for s in
                 ("moses", "ansor-random", "tenset-finetune")}
        assert len(seeds) == len(dev_mod.DEVICES) * 3
        assert all(0 <= s < 2 ** 31 - 1 for s in seeds)


class TestMeasurementSecondsMonotonic:
    """measurement_seconds is the scheduler's cost currency: it must be
    strictly positive and strictly increasing in the repeat count."""

    WLS = [Workload("matmul", (512, 512, 256)),
           Workload("attention", (1024, 64)),
           Workload("scan", (2048, 512))]

    @pytest.mark.parametrize("device", sorted(dev_mod.DEVICES))
    def test_positive_and_monotonic_in_repeats(self, device):
        rng = np.random.RandomState(7)
        for wl in self.WLS:
            for cfg in [default_config(wl), random_config(wl, rng)]:
                prev = 0.0
                for n in (1, 2, 3, 5, 8):
                    s = dev_mod.measurement_seconds(wl, cfg, device,
                                                    n_repeats=n)
                    assert np.isfinite(s) and s > 0.0
                    assert s > prev
                    prev = s

    def test_embedded_parts_pay_larger_fixed_toll(self):
        wl, cfg = self.WLS[0], default_config(self.WLS[0])
        edge = dev_mod.measurement_seconds(wl, cfg, "tpu_edge", n_repeats=1)
        dc = dev_mod.measurement_seconds(wl, cfg, "tpu_v5e", n_repeats=1)
        assert edge > dc
