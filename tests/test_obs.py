"""Unified telemetry suite (ISSUE 8): metrics registry, trace spans,
flight recorder, structured logger.

Pins the contracts the rest of the stack builds on: merge-exact
histograms (one fixed bucket grid, elementwise addition), exact
nearest-rank percentiles off the raw-sample ring, picklable snapshots,
`--stats`-vs-exposition percentile agreement (the LatencyWindow
unification), span-tree wellformedness, cross-process `remote_event`
merging, and the end-to-end `run_campaign(obs=...)` flight-recorder
artifacts with the >=95% wall-time-attribution acceptance gate.
"""
import json
import math
import os
import pickle

import pytest

from repro.obs import (FlightRecorder, LatencyWindow, MetricsRegistry,
                       Tracer, get_logger, metrics as obs_metrics,
                       remote_event, summarize_trace,
                       trace as obs_trace, validate_events)
from repro.obs.metrics import (BUCKET_BOUNDS, Histogram, delta, format_key,
                               hist_percentile, parse_key)


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------


class TestInstruments:
    def test_counter_and_gauge(self):
        reg = MetricsRegistry()
        c = reg.counter("exec.outcomes", backend="thread", ok="true")
        c.inc()
        c.inc(2)
        assert c.value == 3
        # identical (name, labels) -> the same instrument object
        assert reg.counter("exec.outcomes", ok="true",
                           backend="thread") is c
        assert reg.counter("exec.outcomes", ok="false",
                           backend="thread") is not c
        g = reg.gauge("sched.queue_depth")
        g.set(4)
        g.add(-1)
        assert g.value == 3

    def test_histogram_exact_percentiles(self):
        h = Histogram()
        for ms in range(1, 101):
            h.observe(ms / 1e3)
        assert h.percentile(50) == pytest.approx(0.050)
        assert h.percentile(99) == pytest.approx(0.099)
        assert h.count == 100
        assert h.min == pytest.approx(0.001)
        assert h.max == pytest.approx(0.100)

    def test_histogram_merge_is_exact_bucket_addition(self):
        a, b = Histogram(), Histogram()
        for v in (1e-4, 2e-3, 5e-1):
            a.observe(v)
        for v in (3e-4, 7.0):
            b.observe(v)
        merged = Histogram()
        merged.merge_state(a.state())
        merged.merge_state(b.state())
        # order independence
        other = Histogram()
        other.merge_state(b.state())
        other.merge_state(a.state())
        assert merged.state()["counts"] == other.state()["counts"]
        assert merged.count == 5
        assert merged.total == pytest.approx(a.total + b.total)
        elementwise = [x + y for x, y in zip(a.state()["counts"],
                                             b.state()["counts"])]
        assert merged.state()["counts"] == elementwise

    def test_merged_histogram_percentile_bucket_bound(self):
        """Merging a state whose raw-sample ring was dropped in transit
        forces the bucket-resolution fallback — within one grid step above
        the exact percentile, clamped to [min, max]."""
        h = Histogram()
        for ms in range(1, 101):
            h.observe(ms / 1e3)
        st = h.state()
        st["window"] = []                # a peer that shipped buckets only
        merged = Histogram()
        merged.merge_state(st)
        p50 = merged.percentile(50)
        assert 0.001 <= p50 <= 0.100
        # one grid step of 10^(1/8): the fixed-resolution guarantee
        assert 0.050 <= p50 <= 0.050 * 10 ** (1 / 8) + 1e-9

    def test_snapshot_roundtrip_pickle_and_merge(self):
        reg = MetricsRegistry()
        reg.counter("exec.respawns", backend="process").inc(2)
        reg.histogram("exec.queue_wait_seconds",
                      backend="process").observe(0.01)
        snap = pickle.loads(pickle.dumps(reg.snapshot()))
        json.dumps(snap)                 # JSON-able by construction
        other = MetricsRegistry()
        other.merge(snap)
        assert other.counter("exec.respawns",
                             backend="process").value == 2
        h = other.histogram("exec.queue_wait_seconds", backend="process")
        assert h.count == 1 and h.percentile(50) == pytest.approx(0.01)

    def test_format_parse_key_roundtrip(self):
        key = format_key("exec.outcomes",
                         (("backend", "thread"), ("ok", "true")))
        assert key == "exec.outcomes{backend=thread,ok=true}"
        name, labels = parse_key(key)
        assert name == "exec.outcomes"
        assert dict(labels) == {"backend": "thread", "ok": "true"}
        assert parse_key("plain") == ("plain", ())

    def test_delta_between_snapshots(self):
        reg = MetricsRegistry()
        reg.counter("exec.measure_seconds_total").inc(5.0)
        before = reg.snapshot()
        reg.counter("exec.measure_seconds_total").inc(2.5)
        reg.histogram("exec.queue_wait_seconds",
                      backend="thread").observe(0.004)
        d = delta(before, reg.snapshot(), prefixes=("exec.",))
        assert d["counters"]["exec.measure_seconds_total"] == \
            pytest.approx(2.5)
        st = d["histograms"]["exec.queue_wait_seconds{backend=thread}"]
        assert st["count"] == 1
        assert hist_percentile(st, 99) == pytest.approx(0.004)

    def test_registry_stack_current(self):
        base = obs_metrics.current()
        reg = MetricsRegistry()
        obs_metrics.push_registry(reg)
        try:
            assert obs_metrics.current() is reg
        finally:
            obs_metrics.pop_registry(reg)
        assert obs_metrics.current() is base


class TestLatencyWindowUnification:
    """Satellite (b): `--stats` percentile columns and the registry
    exposition must read the SAME samples."""

    def test_stats_summary_equals_exposition(self):
        reg = MetricsRegistry()
        win = LatencyWindow(
            histogram=reg.histogram("serve.latency_seconds", path="hit"))
        for ms in (1, 2, 3, 5, 8, 13, 21, 34):
            win.record(ms / 1e3)
        s = win.summary()
        expo = reg.to_json()["histograms"][
            "serve.latency_seconds{path=hit}"]
        assert s["n"] == expo["count"] == 8
        assert s["p50_ms"] == pytest.approx(expo["p50"] * 1e3)
        assert s["p99_ms"] == pytest.approx(expo["p99"] * 1e3)

    def test_standalone_window_keeps_old_contract(self):
        win = LatencyWindow(capacity=4)
        for v in (0.4, 0.1, 0.2, 0.3):
            win.record(v)
        assert len(win) == 4 and win.count == 4
        assert win.percentile(50) == pytest.approx(0.2)
        win.record(0.5)                  # evicts 0.4
        assert len(win) == 4 and win.count == 5

    def test_text_exposition_lists_instruments(self):
        reg = MetricsRegistry()
        reg.counter("hub.hits").inc(3)
        reg.histogram("hub.latency_seconds", path="hit").observe(0.002)
        text = reg.to_text()
        assert "hub.hits 3" in text
        assert "hub.latency_seconds{path=hit}" in text


# ---------------------------------------------------------------------------
# trace spans
# ---------------------------------------------------------------------------


class TestTracing:
    def test_noop_span_without_tracer(self):
        assert obs_trace.current_tracer() is None
        s = obs_trace.span("tune.round", device="d")
        assert s is obs_trace.NOOP_SPAN
        with s:
            assert obs_trace.current_context() is None

    def test_span_tree_and_validation(self):
        tr = Tracer()
        obs_trace.activate(tr)
        try:
            with obs_trace.span("campaign", strategy="s"):
                for i in range(2):
                    with obs_trace.span("tune.round", step=i + 1):
                        with obs_trace.span("round.measure", n=4):
                            pass
        finally:
            obs_trace.deactivate(tr)
        events = tr.events
        assert len(events) == 5
        assert validate_events(events, expect_root="campaign") == []
        rounds = [e for e in events if e["name"] == "tune.round"]
        root = next(e for e in events if e["name"] == "campaign")
        assert all(e["args"]["parent_id"] == root["args"]["span_id"]
                   for e in rounds)

    def test_exception_closes_span_with_error_status(self):
        tr = Tracer()
        obs_trace.activate(tr)
        try:
            with pytest.raises(ValueError):
                with obs_trace.span("campaign"):
                    with obs_trace.span("tune.round"):
                        raise ValueError("boom")
        finally:
            obs_trace.deactivate(tr)
        by_name = {e["name"]: e for e in tr.events}
        assert by_name["tune.round"]["args"]["status"] == "error"
        assert by_name["campaign"]["args"]["status"] == "error"
        assert validate_events(tr.events) == []

    def test_remote_event_merges_into_tree(self):
        """The farm-worker path: context by value, event dict back."""
        tr = Tracer()
        obs_trace.activate(tr)
        try:
            with obs_trace.span("campaign"):
                with obs_trace.span("round.measure"):
                    ctx = obs_trace.current_context()
                    assert ctx is not None and ctx[0] == tr.trace_id
                    ev = remote_event("exec.measure", ctx, 0.0, 0.001,
                                      status="error", worker="p1", seq=7)
                    tr.add_events([ev])
        finally:
            obs_trace.deactivate(tr)
        assert validate_events(tr.events, expect_root="campaign") == []
        meas = next(e for e in tr.events if e["name"] == "exec.measure")
        assert meas["args"]["parent_id"] == ctx[1]
        assert meas["args"]["status"] == "error"
        assert meas["args"]["span_id"].startswith("r")

    def test_validate_catches_orphans_and_double_roots(self):
        tr = Tracer()
        obs_trace.activate(tr)
        try:
            with obs_trace.span("a"):
                pass
        finally:
            obs_trace.deactivate(tr)
        events = tr.events
        orphan = remote_event("x", (tr.trace_id, "missing"), 0.0, 0.0)
        assert any("orphan" in p
                   for p in validate_events(events + [orphan]))
        second_root = remote_event("y", None, 0.0, 0.0)
        assert any("1 root" in p
                   for p in validate_events(events + [second_root]))
        assert validate_events([]) == ["no span events"]


# ---------------------------------------------------------------------------
# flight recorder + the end-to-end campaign gate
# ---------------------------------------------------------------------------


class TestFlightRecorder:
    def test_artifacts_and_log_sink(self, tmp_path):
        root = str(tmp_path / "obs")
        with FlightRecorder(root) as rec:
            assert obs_metrics.current() is rec.registry
            with obs_trace.span("campaign"):
                obs_metrics.current().counter("sched.grants",
                                              reason="warmup").inc()
            rec.event("grant", step=1, key="d|t")
            get_logger("test-obs").warning("something odd", code=7)
        lines = [json.loads(ln) for ln in
                 open(os.path.join(root, "events.jsonl"))]
        kinds = [e["kind"] for e in lines]
        assert kinds[0] == "recorder_start" and kinds[-1] == "recorder_stop"
        assert "grant" in kinds
        log_evs = [e for e in lines if e["kind"] == "log"]
        assert any(e["msg"] == "something odd" and e["code"] == 7
                   for e in log_evs)
        snap = next(e for e in lines if e["kind"] == "metrics")["snapshot"]
        assert snap["counters"]["sched.grants{reason=warmup}"] == 1
        trace_doc = json.load(
            open(os.path.join(root, "campaign.trace.json")))
        assert validate_events(trace_doc["traceEvents"],
                               expect_root="campaign") == []
        # stop released the registry stack and the tracer
        assert obs_metrics.current() is not rec.registry
        assert obs_trace.current_tracer() is None

    def test_campaign_obs_end_to_end(self, tmp_path):
        """ISSUE 8 acceptance: run_campaign(obs=...) leaves a single-rooted
        complete trace whose summary attributes >=95% of wall time, and
        launch/obs.py --check/--summarize accept the artifacts."""
        import dataclasses

        from repro.autotune.space import Workload
        from repro.configs.moses import DEFAULT as MCFG
        from repro.launch import obs as obs_cli
        from repro.sched import run_campaign

        cfg = dataclasses.replace(MCFG, online_epochs=2,
                                  adaptation_epochs=2, population_size=32,
                                  evolution_rounds=2, top_k_measure=8)
        jobs = [("tpu_v5e", [Workload("matmul", (256, 256, 128), name="a"),
                             Workload("scan", (1024, 512), name="s")])]
        root = str(tmp_path / "obs")
        result = run_campaign(jobs, cfg, strategy="ansor-random",
                              trials_per_task=8, obs=root)
        s = result.obs_summary
        assert s is not None and s["problems"] == []
        assert s["root"] == "campaign"
        assert s["attributed_pct"] >= 95.0
        assert s["error_spans"] == 0
        assert s["by_name"]["exec.measure"]["n"] == \
            result.total_measurements
        assert s["queue_wait"]["n"] == result.total_measurements
        # summarize_trace rounds the counter to 3 decimals
        assert s["measure_seconds_simulated"] == \
            pytest.approx(result.measured_seconds, abs=5e-4)
        assert obs_cli.check(root) == 0
        assert obs_cli.print_summary(root) == 0
        # the tuning result itself is identical to an uninstrumented run
        bare = run_campaign(jobs, cfg, strategy="ansor-random",
                            trials_per_task=8)
        assert bare.curve() == result.curve()

    def test_recorder_ownership_semantics(self, tmp_path):
        """A caller-started recorder passed into run_campaign survives it
        (the caller owns stop); a path string is fully managed."""
        import dataclasses

        from repro.autotune.space import Workload
        from repro.configs.moses import DEFAULT as MCFG
        from repro.sched import run_campaign

        cfg = dataclasses.replace(MCFG, online_epochs=2,
                                  adaptation_epochs=2, population_size=32,
                                  evolution_rounds=2, top_k_measure=8)
        jobs = [("tpu_v5e",
                 [Workload("matmul", (256, 256, 128), name="a")])]
        rec = FlightRecorder(str(tmp_path / "mine")).start()
        try:
            run_campaign(jobs, cfg, strategy="ansor-random",
                         trials_per_task=8, obs=rec)
            assert not rec._stopped
            # two campaigns merge into the caller's one timeline: two
            # campaign roots, so the merged trace is deliberately NOT a
            # single tree until the caller scopes it
            run_campaign(jobs, cfg, strategy="ansor-random",
                         trials_per_task=8, obs=rec)
            roots = [e for e in rec.tracer.events
                     if e["name"] == "campaign"]
            assert len(roots) == 2
        finally:
            rec.stop()
        assert rec._stopped

    def test_summarize_trace_empty(self):
        out = summarize_trace([])
        assert out["problems"] == ["no span events"]


# ---------------------------------------------------------------------------
# structured logger
# ---------------------------------------------------------------------------


class TestLogger:
    def test_level_control_via_env(self, monkeypatch, capsys):
        lg = get_logger("test-obs-log")
        monkeypatch.setenv("REPRO_LOG_LEVEL", "warning")
        lg.info("hidden", a=1)
        lg.warning("shown", path="/x y", n=0.5)
        err = capsys.readouterr().err
        assert "hidden" not in err
        assert "[test-obs-log] WARNING: shown" in err
        assert "path='/x y'" in err and "n=0.5" in err
        monkeypatch.setenv("REPRO_LOG_LEVEL", "debug")
        lg.debug("now visible")
        assert "now visible" in capsys.readouterr().err
        monkeypatch.setenv("REPRO_LOG_LEVEL", "off")
        lg.error("muted")
        assert capsys.readouterr().err == ""

    def test_quiet_under_pytest_by_default(self, monkeypatch, capsys):
        monkeypatch.delenv("REPRO_LOG_LEVEL", raising=False)
        # PYTEST_CURRENT_TEST is set by pytest itself
        get_logger("test-obs-log").info("invisible in tests")
        assert capsys.readouterr().err == ""

    def test_get_logger_is_cached(self):
        assert get_logger("same") is get_logger("same")


# ---------------------------------------------------------------------------
# HubStats as a registry view (the hub.service rewrite)
# ---------------------------------------------------------------------------


class TestHubStatsView:
    def test_counter_backed_fields(self):
        from repro.hub.service import HubStats
        reg = MetricsRegistry()
        st = HubStats(reg)
        assert st.hits == 0
        st.inc("hits")
        st.jobs += 2                     # the += idiom tests rely on
        assert st.hits == 1 and st.jobs == 2
        assert reg.counter("hub.hits").value == 1
        assert reg.counter("hub.jobs").value == 2
        d = st.to_dict()
        assert d["hits"] == 1 and d["jobs"] == 2
        assert "hits=1" in repr(st) and "jobs=2" in repr(st)
