"""Tests for the pluggable Strategy / CostModel interfaces.

* conformance suite every registered `CostModel` must pass (shapes, batched
  parity, training, clone isolation, save/load round-trip),
* strategy-registry behaviour (all five paper strategies registered, unknown
  names fail loudly, user classes plug in),
* the back-compat guarantee: string strategies resolved through the registry
  produce bit-identical `TuneResult`s to the frozen pre-refactor tuner
  (tests/_legacy_tuner.py) on a fixed seed, and string vs instance specs are
  equivalent through both `tune()` and `TuneSession.run()`.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _legacy_tuner import legacy_tune
from repro.autotune.session import TuneSession
from repro.autotune.space import Workload
from repro.autotune.strategies import (STRATEGIES, STRATEGY_REGISTRY,
                                       MosesStrategy, RoundUpdate, Strategy,
                                       register_strategy, resolve_strategy,
                                       strategy_name)
from repro.autotune.tuner import TuneResult, tune
from repro.configs.moses import CostModelConfig, MosesConfig
from repro.core.cost_model import (COST_MODELS, CostModel, MLPCostModel,
                                   Records, ResidualMLPCostModel,
                                   batched_predict, normalize_per_task,
                                   predict, resolve_cost_model,
                                   train_cost_model)

# small config: parity holds for any hyperparameters, so shrink the loop
CM_CFG = CostModelConfig()
FAST_CFG = MosesConfig(online_epochs=3, adaptation_epochs=3,
                       population_size=32, evolution_rounds=2)

TASKS = [Workload("matmul", (256, 256, 128), name="a"),
         Workload("matmul", (256, 512, 128), name="b")]


def _synth_records(n=200, n_tasks=5, seed=0):
    rng = np.random.RandomState(seed)
    x = rng.randn(n, CM_CFG.feature_dim).astype(np.float32)
    raw = (np.abs(rng.randn(n)) + 0.1).astype(np.float32)
    g = (np.arange(n) % n_tasks).astype(np.int32)
    return Records(x=x, y=normalize_per_task(raw, g), g=g, raw_throughput=raw)


@pytest.fixture(scope="module")
def pretrained():
    src = _synth_records()
    model = MLPCostModel(CM_CFG)
    params = model.init(jax.random.PRNGKey(0))
    params, _ = model.train(params, src, epochs=2)
    return src, params


# ---------------------------------------------------------------------------
# CostModel conformance: every registered family must satisfy this contract
# ---------------------------------------------------------------------------


ALL_MODELS = sorted(COST_MODELS)


class TestCostModelConformance:
    @pytest.fixture(params=ALL_MODELS)
    def model(self, request):
        return resolve_cost_model(request.param, CM_CFG)

    def test_registered_and_named(self, model):
        assert isinstance(model, CostModel)
        assert COST_MODELS[model.name] is type(model)

    def test_init_and_predict_shapes(self, model):
        params = model.init(jax.random.PRNGKey(0))
        x = np.random.RandomState(0).randn(9, CM_CFG.feature_dim)
        s = model.predict(params, x.astype(np.float32))
        assert s.shape == (9,)
        assert np.all(np.isfinite(s))

    @pytest.mark.parametrize("n", [1, 8, 9, 33, 130])
    def test_batched_predict_parity(self, model, n):
        """Bucket padding must be invisible: batched == exact, any length."""
        params = model.init(jax.random.PRNGKey(1))
        x = np.random.RandomState(n).randn(n, CM_CFG.feature_dim)
        x = x.astype(np.float32)
        np.testing.assert_allclose(model.batched_predict(params, x),
                                   model.predict(params, x), atol=1e-6)

    def test_empty_batch(self, model):
        params = model.init(jax.random.PRNGKey(0))
        out = model.batched_predict(
            params, np.zeros((0, CM_CFG.feature_dim), np.float32))
        assert out.shape == (0,)

    def test_forward_exposes_hidden(self, model):
        """The adversarial discriminator reads (scores, hidden)."""
        params = model.init(jax.random.PRNGKey(0))
        x = jnp.zeros((4, CM_CFG.feature_dim))
        s, h = model.forward(params, x, return_hidden=True)
        assert s.shape == (4,)
        assert h.shape == (4, model.hidden_dim)

    def test_train_reduces_loss(self, model):
        rec = _synth_records(seed=2)
        params = model.init(jax.random.PRNGKey(2))
        params, losses = model.train(params, rec, epochs=5)
        assert losses[-1] < losses[0]

    def test_clone_params_isolated(self, model):
        """Training a clone must never write through to the original."""
        params = model.init(jax.random.PRNGKey(3))
        before = jax.tree.map(np.asarray, params)
        clone = model.clone_params(params)
        clone, _ = model.train(clone, _synth_records(seed=3), epochs=1)
        for k in before:
            np.testing.assert_array_equal(before[k], np.asarray(params[k]))
        assert any(
            not np.array_equal(np.asarray(clone[k]), before[k])
            for k in before)

    def test_save_load_roundtrip(self, model, tmp_path):
        params = model.init(jax.random.PRNGKey(4))
        path = str(tmp_path / f"{model.name}.npz")
        model.save(params, path)
        loaded = model.load(path)
        x = np.random.RandomState(4).randn(6, CM_CFG.feature_dim)
        x = x.astype(np.float32)
        np.testing.assert_array_equal(model.predict(params, x),
                                      model.predict(loaded, x))


class TestMLPDelegation:
    def test_interface_matches_free_functions_bitwise(self):
        """MLPCostModel goes through the same jit cache as the legacy free
        functions — required for the string-strategy parity guarantee."""
        model = MLPCostModel(CM_CFG)
        params = model.init(jax.random.PRNGKey(0))
        x = np.random.RandomState(0).randn(21, CM_CFG.feature_dim)
        x = x.astype(np.float32)
        np.testing.assert_array_equal(model.predict(params, x),
                                      predict(params, x))
        np.testing.assert_array_equal(model.batched_predict(params, x),
                                      batched_predict(params, x))
        rec = _synth_records(seed=5)
        p1, l1 = model.train(model.clone_params(params), rec, epochs=2)
        p2, l2 = train_cost_model(model.clone_params(params), rec, CM_CFG,
                                  epochs=2)
        assert l1 == l2
        for k in p1:
            np.testing.assert_array_equal(np.asarray(p1[k]),
                                          np.asarray(p2[k]))


# ---------------------------------------------------------------------------
# Strategy registry
# ---------------------------------------------------------------------------


class TestStrategyRegistry:
    def test_all_paper_strategies_registered(self):
        assert STRATEGIES == ("raw", "ansor-random", "tenset-pretrain",
                              "tenset-finetune", "moses")
        for name in STRATEGIES:
            s = resolve_strategy(name)
            assert isinstance(s, Strategy) and s.name == name

    def test_unknown_name_raises_with_choices(self):
        with pytest.raises(KeyError, match="moses"):
            resolve_strategy("no-such-strategy")
        with pytest.raises(KeyError, match="mlp"):
            resolve_cost_model("no-such-model")

    def test_instances_pass_through(self):
        inst = MosesStrategy()
        assert resolve_strategy(inst) is inst
        model = ResidualMLPCostModel(CM_CFG)
        assert resolve_cost_model(model) is model
        assert strategy_name(inst) == "moses" == strategy_name("moses")

    def test_missing_pretrained_fails_loudly(self):
        with pytest.raises(AssertionError, match="pretrained"):
            tune(TASKS[:1], "tpu_v5e", "moses", FAST_CFG, trials_per_task=8)

    def test_user_strategy_plugs_into_tune(self):
        """A new scheme is one registered class — no tuner changes. This one
        has no model at all (params stays None), exercising the random-score
        fallback path."""
        @register_strategy("test-random-search")
        class RandomSearchStrategy(Strategy):
            def on_round(self, builder, feats, round_idx):
                return RoundUpdate(0.0, False)

        try:
            r = tune(TASKS[:1], "tpu_v5e", "test-random-search", FAST_CFG,
                     trials_per_task=16, seed=0)
            assert r.strategy == "test-random-search"
            assert r.tasks[0].measurements == 16
            assert r.tasks[0].best_throughput > 0
        finally:
            del STRATEGY_REGISTRY["test-random-search"]

    def test_evolution_accepts_cost_model(self):
        """evolutionary_search(score_fn=None, cost_model=..., params=...)
        ranks through the interface — identical picks to an explicit
        score_fn over the same model."""
        from repro.autotune.evolution import evolutionary_search
        model = MLPCostModel(CM_CFG)
        params = model.init(jax.random.PRNGKey(0))
        a = evolutionary_search(TASKS[0], None, np.random.RandomState(5),
                                population=32, rounds=1, top_k=8,
                                cost_model=model, params=params)
        b = evolutionary_search(
            TASKS[0], lambda f: model.batched_predict(params, f),
            np.random.RandomState(5), population=32, rounds=1, top_k=8)
        assert [c.knobs for c in a] == [c.knobs for c in b]

    def test_residual_model_swaps_under_paper_strategies(self, pretrained):
        """The second model family runs the full loop — online training
        under ansor-random and lottery-ticket adaptation + AC under moses —
        proving strategies only touch the CostModel interface."""
        model = ResidualMLPCostModel(CM_CFG, width=64, depth=2)
        r = tune(TASKS[:1], "tpu_edge", "ansor-random", FAST_CFG,
                 trials_per_task=16, seed=1, cost_model=model)
        assert r.tasks[0].best_throughput > 0

        src = _synth_records(seed=7)
        params = model.init(jax.random.PRNGKey(7))
        params, _ = model.train(params, src, epochs=2)
        r = tune(TASKS[:1], "tpu_edge", "moses", FAST_CFG, trials_per_task=16,
                 pretrained_params=params, source_pool=src, seed=1,
                 cost_model=model)
        assert r.tasks[0].best_throughput > 0


# ---------------------------------------------------------------------------
# Back-compat: registry-resolved strings == the pre-refactor if/elif tuner
# ---------------------------------------------------------------------------


def _assert_results_identical(a: TuneResult, b: TuneResult):
    assert a.strategy == b.strategy and a.device == b.device
    assert a.total_search_seconds == b.total_search_seconds
    assert len(a.tasks) == len(b.tasks)
    for ta, tb in zip(a.tasks, b.tasks):
        assert ta.best_config.knobs == tb.best_config.knobs
        assert ta.best_throughput == tb.best_throughput
        assert ta.best_latency == tb.best_latency
        assert ta.measurements == tb.measurements
        assert ta.search_seconds == tb.search_seconds
        assert ta.trajectory == tb.trajectory


class TestLegacyParity:
    @pytest.mark.parametrize("strategy", STRATEGIES)
    def test_string_strategy_bit_identical_to_legacy(self, strategy,
                                                     pretrained):
        src, params = pretrained
        kwargs = dict(trials_per_task=16, seed=3)
        if strategy in ("tenset-pretrain", "tenset-finetune", "moses"):
            kwargs["pretrained_params"] = params
        if strategy == "moses":
            kwargs["source_pool"] = src
        old = legacy_tune(TASKS, "tpu_edge", strategy, FAST_CFG, **kwargs)
        new = tune(TASKS, "tpu_edge", strategy, FAST_CFG, **kwargs)
        _assert_results_identical(old, new)

    def test_instance_spec_matches_string_spec(self, pretrained):
        src, params = pretrained
        kwargs = dict(trials_per_task=16, pretrained_params=params,
                      source_pool=src, seed=4)
        by_name = tune(TASKS, "tpu_edge", "moses", FAST_CFG, **kwargs)
        by_inst = tune(TASKS, "tpu_edge", MosesStrategy(), FAST_CFG, **kwargs)
        _assert_results_identical(by_name, by_inst)

    def test_session_string_and_instance_agree(self, pretrained):
        src, params = pretrained
        session = TuneSession(moses_cfg=FAST_CFG, pretrained_params=params,
                              source_pool=src, seed=2, trials_per_task=16)
        by_name = session.run(TASKS[:1], "tpu_edge", "tenset-finetune")
        by_inst = session.run(
            TASKS[:1], "tpu_edge",
            resolve_strategy("tenset-finetune"))
        _assert_results_identical(by_name, by_inst)
        assert len(session.results) == 2

    def test_session_rejects_unknown_strategy(self):
        session = TuneSession(moses_cfg=FAST_CFG)
        with pytest.raises(KeyError):
            session.run(TASKS[:1], "tpu_edge", "nope")
