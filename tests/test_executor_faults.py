"""Fault-injection suite for the measurement farm (ISSUE 6).

The failure semantics the executor *claims* — fault isolation, crash
quarantine, timeout-kill-and-respawn, pool-starvation immunity, bit-exact
serial/parallel replay — proven against deterministic injected faults
(`devices.FaultInjector`) instead of asserted in docstrings. The shared
contracts run parametrized over BOTH backends; process-only lifecycle tests
(hard kill, heartbeat, pinning) and the thread watchdog regression follow.

Everything here must stay picklable where the process backend is involved:
fault functions live at module level, and the injector itself is a
picklable dataclass (each spawn worker gets its own copy — per-worker
transient state, like a power-cycled board).
"""
import os
import signal
import subprocess
import sys
import time

import numpy as np
import pytest

from repro.autotune import devices as dev_mod
from repro.autotune.devices import FaultInjector, InjectedCrash
from repro.autotune.space import Workload, default_config, random_config
from repro.sched import (MeasurementExecutor, ProcessMeasurementExecutor,
                         ThreadMeasurementExecutor, resolve_executor,
                         run_campaign)

WL = Workload("matmul", (256, 256, 128), name="wl")
BACKENDS = ["thread", "process"]


def _configs(n, seed=0):
    rng = np.random.RandomState(seed)
    out, seen = [], set()
    while len(out) < n:
        c = random_config(WL, rng)
        if c.knobs not in seen:
            seen.add(c.knobs)
            out.append(c)
    return out


def _split_by_fault(injector, cfgs, kind, trial=0):
    """(configs drawing `kind`, configs drawing no fault)."""
    hit = [c for c in cfgs if injector.fault_for(WL, c, trial) == kind]
    clean = [c for c in cfgs if injector.fault_for(WL, c, trial) is None]
    return hit, clean


def _injector(backend, **kw):
    """Crash mode per backend: the process farm takes real worker death
    (`os._exit`), the thread pool its in-process stand-in (InjectedCrash).
    Same seed => same fault map, so cross-backend replays stay comparable."""
    return FaultInjector(kill_process=(backend == "process"), **kw)


def _pin_enforcing_measure(wl, cfg, device, trial=0):
    """Module-level (picklable) measure_fn that fails unless the worker's
    exported device pin matches the request — proves dispatch affinity."""
    pin = os.environ.get("REPRO_WORKER_DEVICE")
    if pin is not None and pin != device:
        raise AssertionError(f"request for {device} ran on worker "
                             f"pinned to {pin}")
    return dev_mod.measure(wl, cfg, device, trial=trial)


# ---------------------------------------------------------------------------
# shared contracts, both backends
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", BACKENDS)
class TestBackendContracts:
    def test_isinstance_dispatch(self, backend):
        with MeasurementExecutor(workers=1, backend=backend) as ex:
            assert isinstance(ex, MeasurementExecutor)
            assert isinstance(ex, ThreadMeasurementExecutor
                              if backend == "thread"
                              else ProcessMeasurementExecutor)
            assert ex.backend == backend

    def test_submission_order_and_serial_identity(self, backend):
        cfgs = _configs(12)
        with MeasurementExecutor(workers=4, backend=backend) as ex:
            outs = ex.measure_batch(WL, cfgs, "tpu_v5e", trial=3)
        assert [o.request.config for o in outs] == cfgs
        serial = [dev_mod.measure(WL, c, "tpu_v5e", trial=3) for c in cfgs]
        # bit-identical, not allclose: parallel replay == serial replay
        assert [o.throughput for o in outs] == serial

    def test_crash_poisons_exactly_one_config(self, backend):
        """ISSUE 6 acceptance: one injected crash fails one config; every
        other result is bit-identical to the fault-free serial run."""
        fi = _injector(backend, crash=0.2, seed=7)
        hit, clean = _split_by_fault(fi, _configs(16), "crash")
        cfgs = hit[:1] + clean[:11]     # exactly one hostile config
        with MeasurementExecutor(workers=3, backend=backend, retries=0,
                                 measure_fn=fi) as ex:
            outs = ex.measure_batch(WL, cfgs, "tpu_v5p")
            assert not outs[0].ok and outs[0].error
            assert outs[0].seconds > 0      # the dead board still cost time
            serial = [dev_mod.measure(WL, c, "tpu_v5p") for c in cfgs[1:]]
            assert [o.throughput for o in outs[1:]] == serial
            q = ex.quarantined()
            assert len(q) == 1
            assert q[0].knobs == cfgs[0].knobs and q[0].trial == 0
            assert ex.is_quarantined(WL, cfgs[0], 0)
            assert not ex.is_quarantined(WL, cfgs[1], 0)

    def test_quarantine_blocks_resubmission(self, backend):
        fi = _injector(backend, crash=0.2, seed=7)
        hit, clean = _split_by_fault(fi, _configs(16), "crash")
        cfgs = hit[:2] + clean[:4]
        with MeasurementExecutor(workers=2, backend=backend, retries=0,
                                 measure_fn=fi) as ex:
            first = ex.measure_batch(WL, cfgs, "tpu_v5p")
            assert [not o.ok for o in first[:2]] == [True, True]
            spawned = ex.respawns
            again = ex.measure_batch(WL, cfgs, "tpu_v5p")
            for o in again[:2]:
                # resolved from the quarantine record: the grenade was never
                # handed to a fresh worker, so nothing was paid or respawned
                assert o.error.startswith("quarantined:")
                assert o.seconds == 0.0 and o.attempts == 0
            assert [o.throughput for o in again[2:]] == \
                [o.throughput for o in first[2:]]
            assert ex.respawns == spawned
            assert len(ex.quarantined()) == 2

    def test_quarantine_persists_across_retry_rounds(self, backend):
        """A campaign-style retry loop can resubmit failures every round;
        the poisoned identity must short-circuit each time, forever."""
        fi = _injector(backend, crash=0.2, seed=7)
        hit, _ = _split_by_fault(fi, _configs(16), "crash")
        bad = hit[0]
        with MeasurementExecutor(workers=1, backend=backend, retries=0,
                                 measure_fn=fi) as ex:
            errors = [ex.measure_batch(WL, [bad], "tpu_v5p")[0].error
                      for _ in range(4)]
        assert not errors[0].startswith("quarantined:")
        assert all(e.startswith("quarantined:") for e in errors[1:])

    def test_flaky_transient_recovers_with_retry(self, backend):
        fi = _injector(backend, flaky=0.99, seed=11)
        cfgs = _configs(6)
        assert all(fi.fault_for(WL, c, 0) == "flaky" for c in cfgs)
        with MeasurementExecutor(workers=2, backend=backend, retries=2,
                                 backoff_s=0.001, measure_fn=fi) as ex:
            outs = ex.measure_batch(WL, cfgs, "tpu_v5e")
        serial = [dev_mod.measure(WL, c, "tpu_v5e") for c in cfgs]
        assert [o.throughput for o in outs] == serial
        assert all(o.attempts == 2 for o in outs)       # failed, then passed
        assert all(o.seconds > 0 for o in outs)

    def test_slow_degrade_is_not_quarantined(self, backend):
        """A degraded-but-healthy board answers late and correctly; with a
        timeout above its latency it must never be treated as poisoned."""
        fi = _injector(backend, slow=0.99, slow_s=0.05, seed=5)
        cfgs = _configs(4)
        with MeasurementExecutor(workers=2, backend=backend, timeout_s=30.0,
                                 measure_fn=fi) as ex:
            outs = ex.measure_batch(WL, cfgs, "tpu_v5e")
            assert all(o.ok for o in outs)
            assert ex.quarantined() == []

    def test_timeout_is_quarantined_and_charged(self, backend):
        fi = _injector(backend, hang=0.2, seed=3, hang_s=30.0)
        hit, clean = _split_by_fault(fi, _configs(16), "hang")
        cfgs = hit[:1] + clean[:3]
        with MeasurementExecutor(workers=2, backend=backend, retries=0,
                                 timeout_s=0.5, measure_fn=fi) as ex:
            outs = ex.measure_batch(WL, cfgs, "tpu_v5p")
            assert not outs[0].ok and "timeout" in outs[0].error
            # a wedged task must not look CHEAP to the scheduler's
            # gain/cost priority: the occupied board is still charged
            assert outs[0].seconds > 0
            assert all(o.ok for o in outs[1:])
            assert ex.is_quarantined(WL, cfgs[0], 0)

    def test_bounded_queue_backpressure(self, backend):
        with MeasurementExecutor(workers=2, queue_size=2,
                                 backend=backend) as ex:
            outs = ex.measure_batch(WL, _configs(12), "tpu_v5e")
        assert all(o.ok for o in outs)

    def test_submit_after_shutdown_raises(self, backend):
        ex = MeasurementExecutor(workers=1, backend=backend)
        ex.shutdown()
        with pytest.raises(RuntimeError):
            ex.submit(WL, default_config(WL), "tpu_v5e")

    def test_trial_keys_fault_identity(self, backend):
        """Faults key on (config, trial): the trial that crashed stays
        quarantined while another trial of the same config still runs."""
        fi = _injector(backend, crash=0.2, seed=7)
        bad = _split_by_fault(fi, _configs(16), "crash")[0][0]
        other = next(t for t in range(1, 50)
                     if fi.fault_for(WL, bad, t) is None)
        with MeasurementExecutor(workers=1, backend=backend, retries=0,
                                 measure_fn=fi) as ex:
            assert not ex.measure_batch(WL, [bad], "tpu_v5p", trial=0)[0].ok
            ok = ex.measure_batch(WL, [bad], "tpu_v5p", trial=other)[0]
            assert ok.ok
            assert ex.is_quarantined(WL, bad, 0)
            assert not ex.is_quarantined(WL, bad, other)


# ---------------------------------------------------------------------------
# process farm lifecycle
# ---------------------------------------------------------------------------


class TestProcessFarm:
    def test_worker_death_respawns_and_pool_keeps_serving(self):
        fi = _injector("process", crash=0.2, seed=7)
        hit, clean = _split_by_fault(fi, _configs(20), "crash")
        with MeasurementExecutor(workers=2, backend="process", retries=0,
                                 measure_fn=fi) as ex:
            outs = ex.measure_batch(WL, hit[:2] + clean[:4], "tpu_v5p")
            assert sum(not o.ok for o in outs) == 2
            assert ex.respawns >= 2
            assert len(ex._farm) == 2       # the pool never shrank
            # clean follow-up batch proves the respawned workers serve
            outs2 = ex.measure_batch(WL, clean[4:8], "tpu_v5p")
            assert all(o.ok for o in outs2)

    def test_timeout_hard_kills_and_respawns(self):
        fi = _injector("process", hang=0.25, seed=3, hang_s=60.0)
        hit, clean = _split_by_fault(fi, _configs(20), "hang")
        with MeasurementExecutor(workers=2, backend="process", retries=0,
                                 timeout_s=0.4, measure_fn=fi) as ex:
            t0 = time.monotonic()
            outs = ex.measure_batch(WL, hit[:2] + clean[:2], "tpu_v5p")
            # the wedge was KILLED, not waited out (hang_s=60)
            assert time.monotonic() - t0 < 30.0
            assert [not o.ok for o in outs[:2]] == [True, True]
            assert all("timeout" in o.error for o in outs[:2])
            assert all(o.ok for o in outs[2:])
            assert ex.respawns >= 2

    def test_pool_starvation_under_repeated_hangs(self):
        """Every candidate wedges: the farm must keep killing/respawning and
        measure_batch must return — starvation can never deadlock it."""
        fi = _injector("process", hang=1.0, seed=1, hang_s=60.0)
        cfgs = _configs(6)
        with MeasurementExecutor(workers=2, backend="process", retries=0,
                                 timeout_s=0.4, measure_fn=fi) as ex:
            outs = ex.measure_batch(WL, cfgs, "tpu_v5p")
            assert all(not o.ok for o in outs)
            assert ex.respawns >= len(cfgs)
            # and the pool is still alive for honest work afterwards
            ok = ex.measure_batch(WL, [default_config(WL)], "tpu_v5p",
                                  trial=1)[0]
            assert ok.ok or "quarantined" not in (ok.error or "")

    def test_heartbeat_detects_frozen_worker(self):
        """A SIGSTOPped process is alive but frozen — no timeout timer is
        armed (it is idle), so only the heartbeat can catch it."""
        with MeasurementExecutor(workers=1, backend="process",
                                 heartbeat_s=0.05, hb_grace_s=0.5) as ex:
            assert ex.measure_batch(WL, _configs(1), "tpu_v5e")[0].ok
            victim = ex._farm[0].proc
            os.kill(victim.pid, signal.SIGSTOP)
            deadline = time.monotonic() + 15.0
            while ex.respawns < 1 and time.monotonic() < deadline:
                time.sleep(0.05)
            assert ex.respawns >= 1, "frozen worker never detected"
            assert all(o.ok for o in
                       ex.measure_batch(WL, _configs(2, seed=2), "tpu_v5e"))
            assert not victim.is_alive()

    def test_device_pinning_routes_requests(self):
        pins = ["tpu_v5p", "tpu_v5e"]
        with MeasurementExecutor(workers=2, backend="process",
                                 device_pins=pins,
                                 measure_fn=_pin_enforcing_measure) as ex:
            assert {w.pin for w in ex._farm} == set(pins)
            for dev in pins:        # the enforcing fn raises on a mis-route
                outs = ex.measure_batch(WL, _configs(4), dev)
                assert all(o.ok for o in outs), [o.error for o in outs]
                assert all(o.worker.endswith(dev) for o in outs)
            # a device outside the pin set still gets served (any worker)
            with MeasurementExecutor(workers=2, backend="process",
                                     device_pins=pins) as ex2:
                assert ex2.measure_batch(WL, _configs(1), "tpu_edge")[0].ok

    def test_unpicklable_measure_fn_fails_fast(self):
        with pytest.raises(TypeError, match="pickle"):
            MeasurementExecutor(backend="process",
                                measure_fn=lambda wl, cfg, d, trial=0: 1.0)

    def test_unknown_backend_rejected(self):
        with pytest.raises(ValueError, match="unknown executor backend"):
            MeasurementExecutor(backend="fiber")

    def test_resolve_executor_specs(self):
        ex, owned = resolve_executor(None)
        assert isinstance(ex, ThreadMeasurementExecutor) and owned
        ex.shutdown()
        ex, owned = resolve_executor("process", workers=1)
        assert isinstance(ex, ProcessMeasurementExecutor) and owned
        ex.shutdown()
        with MeasurementExecutor(workers=1) as mine:
            got, owned = resolve_executor(mine)
            assert got is mine and not owned
        with pytest.raises(ValueError):
            resolve_executor("fiber")


# ---------------------------------------------------------------------------
# thread watchdog (satellite: the stale-slot leak)
# ---------------------------------------------------------------------------


class TestThreadWatchdog:
    def test_consecutive_timeouts_cannot_deadlock_measure_batch(self):
        """Regression for the stale-slot leak: pre-watchdog, `workers`
        wedged measurements occupied their pool slots forever and every
        later batch deadlocked. N > workers consecutive timeouts must now
        finish AND leave a serving pool behind."""
        import threading
        release = threading.Event()

        def wedge_all(wl, cfg, device, trial=0):
            release.wait(20.0)
            return dev_mod.measure(wl, cfg, device, trial=trial)

        try:
            with MeasurementExecutor(workers=2, timeout_s=0.15,
                                     measure_fn=wedge_all) as ex:
                for round_i in range(2):    # two full batches of wedges
                    outs = ex.measure_batch(WL, _configs(4, seed=round_i),
                                            "tpu_v5e", trial=round_i)
                    assert all(not o.ok and "timeout" in o.error
                               for o in outs)
                assert ex.respawns >= 4     # retired + topped back up
        finally:
            release.set()                   # let retired threads exit

    def test_retired_worker_stale_result_is_dropped(self):
        import threading
        release = threading.Event()
        wedged_knobs = _configs(1, seed=9)[0].knobs

        def wedge_one(wl, cfg, device, trial=0):
            if cfg.knobs == wedged_knobs:
                release.wait(20.0)
            return dev_mod.measure(wl, cfg, device, trial=trial)

        with MeasurementExecutor(workers=2, timeout_s=0.15,
                                 measure_fn=wedge_one) as ex:
            out = ex.measure_batch(WL, _configs(1, seed=9), "tpu_v5e")[0]
            assert not out.ok and "timeout" in out.error
            release.set()                   # the wedge now "recovers"...
            time.sleep(0.1)
            # ...but its identity stays quarantined and its late result
            # was dropped (first-writer-wins), never resurrected
            again = ex.measure_batch(WL, _configs(1, seed=9), "tpu_v5e")[0]
            assert again.error.startswith("quarantined:")

    def test_pool_tops_up_to_constant_size(self):
        import threading
        release = threading.Event()

        def wedge_all(wl, cfg, device, trial=0):
            release.wait(20.0)
            return dev_mod.measure(wl, cfg, device, trial=trial)

        try:
            with MeasurementExecutor(workers=3, timeout_s=0.1,
                                     measure_fn=wedge_all) as ex:
                ex.measure_batch(WL, _configs(3), "tpu_v5e")
                live = [w for w in ex._workers if not w.retired]
                assert len(live) == 3
        finally:
            release.set()


# ---------------------------------------------------------------------------
# campaign replay under faults + spawn determinism
# ---------------------------------------------------------------------------


def _tiny_cfg():
    import dataclasses

    from repro.configs.moses import DEFAULT as MCFG
    return dataclasses.replace(MCFG, online_epochs=2, adaptation_epochs=2,
                               population_size=32, evolution_rounds=2,
                               top_k_measure=8)


CAMPAIGN_JOBS = [("tpu_v5e", [Workload("matmul", (256, 256, 128), name="a"),
                              Workload("scan", (1024, 512), name="s")])]


class TestCampaignReplay:
    def test_process_campaign_matches_thread_campaign(self):
        """The whole gradient campaign, measured through spawn workers,
        lands bit-identical results to the in-process thread pool."""
        base = run_campaign(CAMPAIGN_JOBS, _tiny_cfg(),
                            strategy="ansor-random", trials_per_task=16)
        farm = run_campaign(CAMPAIGN_JOBS, _tiny_cfg(),
                            strategy="ansor-random", trials_per_task=16,
                            executor="process")
        assert farm.curve() == base.curve()
        for r1, r2 in zip(base.results, farm.results):
            for t1, t2 in zip(r1.tasks, r2.tasks):
                assert t1.best_config.knobs == t2.best_config.knobs
                assert t1.best_latency == t2.best_latency
                assert t1.measured == t2.measured

    def test_faulted_campaign_replays_identically_across_backends(self):
        """ISSUE 6 tentpole: under the SAME injected fault map, a campaign
        measured serially (1 thread worker, in-process crashes) and one
        measured by the farm (4 spawn workers, real worker deaths) agree
        bit-exactly — worker death is semantically an exception, and the
        quarantine keeps both sides' retry behavior aligned."""
        runs = []
        for backend, workers in (("thread", 1), ("process", 4)):
            fi = _injector(backend, crash=0.08, seed=13)
            ex = MeasurementExecutor(workers=workers, backend=backend,
                                     retries=0, measure_fn=fi)
            try:
                runs.append(run_campaign(
                    CAMPAIGN_JOBS, _tiny_cfg(), strategy="ansor-random",
                    trials_per_task=16, executor=ex))
            finally:
                ex.shutdown()
        serial, farm = runs
        assert farm.curve() == serial.curve()
        poisoned = [[(c.knobs, t) for c, t, _ in (tk.poisoned or [])]
                    for r in farm.results for tk in r.tasks]
        assert poisoned == [[(c.knobs, t) for c, t, _ in (tk.poisoned or [])]
                            for r in serial.results for tk in r.tasks]
        assert any(poisoned), "fault map never fired; raise crash= or reseed"
        for r1, r2 in zip(serial.results, farm.results):
            for t1, t2 in zip(r1.tasks, r2.tasks):
                assert t1.measured == t2.measured

    def test_spawn_campaign_immune_to_pythonhashseed(self):
        """Satellite: the same campaign in-process and via spawn workers
        under PYTHONHASHSEED variation yields a bit-identical curve()."""
        in_process = run_campaign(CAMPAIGN_JOBS, _tiny_cfg(),
                                  strategy="ansor-random",
                                  trials_per_task=8).curve()
        code = (
            "import dataclasses\n"
            "from repro.autotune.space import Workload\n"
            "from repro.configs.moses import DEFAULT as MCFG\n"
            "from repro.sched import run_campaign\n"
            "cfg = dataclasses.replace(MCFG, online_epochs=2,"
            " adaptation_epochs=2, population_size=32, evolution_rounds=2,"
            " top_k_measure=8)\n"
            "jobs = [('tpu_v5e', [Workload('matmul', (256, 256, 128),"
            " name='a'), Workload('scan', (1024, 512), name='s')])]\n"
            "print(repr(run_campaign(jobs, cfg, strategy='ansor-random',"
            " trials_per_task=8, executor='process').curve()))\n")
        curves = []
        for hashseed in ("0", "31337"):
            out = subprocess.run(
                [sys.executable, "-c", code], capture_output=True,
                text=True, check=True,
                env={"PYTHONHASHSEED": hashseed, "PYTHONPATH": "src",
                     "JAX_PLATFORMS": "cpu", "PATH": os.environ["PATH"],
                     "HOME": os.environ.get("HOME", "/tmp")},
                cwd=os.path.join(os.path.dirname(__file__), ".."))
            curves.append(eval(out.stdout.strip().splitlines()[-1]))
        assert curves[0] == curves[1]
        assert curves[0] == in_process


# ---------------------------------------------------------------------------
# the injector itself
# ---------------------------------------------------------------------------


class TestFaultInjector:
    def test_fault_map_is_deterministic_and_disjoint(self):
        fi = FaultInjector(crash=0.1, hang=0.1, flaky=0.1, slow=0.1, seed=2)
        cfgs = _configs(64)
        m1 = [fi.fault_for(WL, c, 0) for c in cfgs]
        m2 = [FaultInjector(crash=0.1, hang=0.1, flaky=0.1, slow=0.1,
                            seed=2).fault_for(WL, c, 0) for c in cfgs]
        assert m1 == m2
        kinds = set(m1)
        assert kinds <= {None, "crash", "hang", "flaky", "slow"}
        assert len(kinds - {None}) >= 3     # rates actually draw faults
        # a different seed reshuffles the map
        m3 = [FaultInjector(crash=0.1, hang=0.1, flaky=0.1, slow=0.1,
                            seed=3).fault_for(WL, c, 0) for c in cfgs]
        assert m3 != m1

    def test_healthy_identities_measure_exactly(self):
        fi = FaultInjector(crash=0.3, seed=7)
        clean = _split_by_fault(fi, _configs(16), "crash")[1][:4]
        for c in clean:     # fault identity keys on trial too: stay on 0
            assert fi(WL, c, "tpu_v5e", trial=0) == \
                dev_mod.measure(WL, c, "tpu_v5e", trial=0)

    def test_crash_raises_in_process(self):
        fi = FaultInjector(crash=0.3, seed=7)      # kill_process=False
        bad = _split_by_fault(fi, _configs(16), "crash")[0][0]
        with pytest.raises(InjectedCrash):
            fi(WL, bad, "tpu_v5e")

    def test_flaky_fails_once_then_recovers(self):
        fi = FaultInjector(flaky=0.99, seed=11)
        cfg = _configs(1)[0]
        assert fi.fault_for(WL, cfg, 0) == "flaky"
        with pytest.raises(OSError):
            fi(WL, cfg, "tpu_v5e")
        assert fi(WL, cfg, "tpu_v5e") == dev_mod.measure(WL, cfg, "tpu_v5e")


# ---------------------------------------------------------------------------
# cross-process span propagation under faults (ISSUE 8 satellite)
# ---------------------------------------------------------------------------


class TestCampaignTelemetryUnderFaults:
    def test_farm_trace_single_rooted_with_error_spans(self, tmp_path):
        """ISSUE 8 acceptance: a farm campaign with tracing enabled, under
        FaultInjector worker kills, yields ONE well-formed trace — every
        worker's exec.measure spans parent into the campaign tree (no
        orphans), and killed workers' in-flight spans are closed with
        status=error instead of dropped."""
        from repro.obs import FlightRecorder, validate_events

        fi = _injector("process", crash=0.08, seed=13)
        ex = MeasurementExecutor(workers=4, backend="process", retries=0,
                                 measure_fn=fi)
        rec = FlightRecorder(str(tmp_path / "obs"))
        try:
            result = run_campaign(CAMPAIGN_JOBS, _tiny_cfg(),
                                  strategy="ansor-random",
                                  trials_per_task=16, executor=ex, obs=rec)
        finally:
            ex.shutdown()
        events = rec.tracer.events
        assert validate_events(events, expect_root="campaign") == []

        meas = [e for e in events if e.get("name") == "exec.measure"]
        assert meas, "no exec.measure spans came back over the farm pipes"
        # spans were built IN the worker processes, not synthesized locally
        worker_pids = {e["pid"] for e in meas} - {os.getpid()}
        assert worker_pids, "all exec.measure spans carry the parent pid"

        poisoned = sum(len(tk.poisoned or [])
                       for r in result.results for tk in r.tasks)
        assert poisoned > 0, "fault map never fired; raise crash= or reseed"
        errors = [e for e in meas if e["args"]["status"] == "error"]
        assert len(errors) >= poisoned
        # the killed workers' spans were synthesized by the parent at
        # respawn time (the worker died before it could answer)
        killed = [e for e in errors if e["pid"] == os.getpid()]
        assert killed, "no parent-synthesized span for a killed worker"
        assert all("died" in str(e["args"].get("error", ""))
                   for e in killed)

        # every measure span parents to a live round.measure/tune.finish
        ids = {e["args"]["span_id"] for e in events if e.get("ph") == "X"}
        assert all(e["args"]["parent_id"] in ids for e in meas)

        summary = result.obs_summary
        assert summary["problems"] == []
        assert summary["attributed_pct"] >= 95.0
        assert summary["error_spans"] >= poisoned

    def test_telemetry_does_not_perturb_faulted_replay(self, tmp_path):
        """The instrumented farm campaign lands bit-identical results to
        the uninstrumented one under the same fault map — observability
        must never change what was measured."""
        curves = []
        for obs in (None, str(tmp_path / "obs")):
            fi = _injector("process", crash=0.08, seed=13)
            ex = MeasurementExecutor(workers=4, backend="process",
                                     retries=0, measure_fn=fi)
            try:
                curves.append(run_campaign(
                    CAMPAIGN_JOBS, _tiny_cfg(), strategy="ansor-random",
                    trials_per_task=16, executor=ex, obs=obs).curve())
            finally:
                ex.shutdown()
        assert curves[0] == curves[1]
