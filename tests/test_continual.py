"""Continual Learning & Model Lifecycle tests.

Covers: replay determinism (in-process and cross-process, mirroring the
fingerprint determinism test), class balance and mixing; the mask-anchored
continual update (anchored params stay near the anchor, free params move);
drift detectors (typed reports, no-baseline semantics); versioned model
lineage in the store (parent chain, retire, family mismatch, legacy
flat-file fallback); store.compact() (duplicate + torn-line handling);
ModelLifecycle state machine + the held-out no-regression guard; the
TuningHub refresh integration; and the launch.hub --stats drift column.
"""
import dataclasses
import json
import math
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.autotune.space import ProgramConfig, Workload, default_config
from repro.configs.moses import DEFAULT as MCFG
from repro.continual import (CALIBRATION, FINGERPRINT, LifecycleConfig,
                             ModelLifecycle, ReplayBuffer, ReplayConfig,
                             anchor_weights, anchored_train, build_records,
                             calibration_drift, detect_drift, device_rows,
                             fingerprint_drift, newest_records, split_tail)
from repro.core.cost_model import (Records, pairwise_rank_accuracy,
                                   param_distance, rank_accuracy,
                                   resolve_cost_model, save_params)
from repro.hub import RecordStore, bootstrap_store, device_fingerprint
from repro.hub.store import SCHEMA_VERSION

WL_A = Workload("matmul", (256, 256, 128), name="a")
WL_B = Workload("matmul", (512, 256, 128), name="b")
CFG_A = default_config(WL_A)

TINY_CFG = dataclasses.replace(
    MCFG, online_epochs=2, adaptation_epochs=2, population_size=32,
    evolution_rounds=2, top_k_measure=8)

TINY_LC = LifecycleConfig(window=8, min_fresh=4, refresh_epochs=2,
                          replay=ReplayConfig(per_task=8))


def _boot(store, devices=("tpu_v5e",), n=16):
    return bootstrap_store(store, devices, [WL_A, WL_B],
                           programs_per_task=n)


# ---------------------------------------------------------------------------
# cost-model helpers
# ---------------------------------------------------------------------------


class TestRankAccuracy:
    def test_perfect_and_inverted(self):
        y = np.array([0.1, 0.5, 1.0], np.float32)
        g = np.zeros(3, np.int32)
        assert pairwise_rank_accuracy(y, y, g) == 1.0
        assert pairwise_rank_accuracy(-y, y, g) == 0.0

    def test_ties_in_labels_skipped(self):
        y = np.array([1.0, 1.0, 0.5], np.float32)
        s = np.array([0.0, 9.0, -1.0], np.float32)
        g = np.zeros(3, np.int32)
        # only the two (tied-free) pairs against the 0.5 row count
        assert pairwise_rank_accuracy(s, y, g) == 1.0

    def test_no_pairs_is_nan(self):
        assert math.isnan(pairwise_rank_accuracy(
            np.zeros(2), np.ones(2), np.array([0, 1])))

    def test_groups_respected(self):
        # cross-group inversions must not count
        y = np.array([0.1, 1.0, 1.0, 0.1], np.float32)
        s = np.array([0.0, 1.0, 0.0, 1.0], np.float32)
        g = np.array([0, 0, 1, 1], np.int32)
        assert pairwise_rank_accuracy(s, y, g) == 0.5

    def test_rank_accuracy_on_records(self):
        x = np.random.RandomState(0).randn(16, 164).astype(np.float32)
        recs = Records(x=x, y=np.linspace(0, 1, 16).astype(np.float32),
                       g=np.zeros(16, np.int32))
        model = resolve_cost_model("mlp", MCFG.cost_model)
        params = model.init(jax.random.PRNGKey(0))
        acc = rank_accuracy(params, recs, predict_fn=model.batched_predict)
        assert 0.0 <= acc <= 1.0


class TestParamDistance:
    def test_identity_zero(self):
        p = {"w": np.ones((3, 3), np.float32)}
        assert param_distance(p, p) == 0.0

    def test_mask_restricts(self):
        a = {"w": np.ones(4, np.float32), "v": np.ones(4, np.float32)}
        b = {"w": np.ones(4, np.float32), "v": np.zeros(4, np.float32)}
        only_w = {"w": np.ones(4, np.float32), "v": np.zeros(4, np.float32)}
        assert param_distance(a, b) > 0
        assert param_distance(a, b, mask=only_w) == 0.0


# ---------------------------------------------------------------------------
# replay
# ---------------------------------------------------------------------------


class TestReplay:
    def _store(self, tmp_path, n=16):
        store = RecordStore(str(tmp_path / "s"))
        _boot(store, n=n)
        return store

    def test_deterministic_in_process(self, tmp_path):
        store = self._store(tmp_path)
        a = ReplayBuffer(store, "tpu_v5e", ReplayConfig(per_task=8)).sample()
        b = ReplayBuffer(store, "tpu_v5e", ReplayConfig(per_task=8)).sample()
        np.testing.assert_array_equal(a.x, b.x)
        np.testing.assert_array_equal(a.raw_throughput, b.raw_throughput)

    def test_seed_changes_sample(self, tmp_path):
        store = self._store(tmp_path, n=32)
        a = ReplayBuffer(store, "tpu_v5e",
                         ReplayConfig(per_task=8, seed=0)).sample()
        b = ReplayBuffer(store, "tpu_v5e",
                         ReplayConfig(per_task=8, seed=1)).sample()
        assert not np.array_equal(a.raw_throughput, b.raw_throughput)

    def test_deterministic_across_processes(self, tmp_path):
        """Same seed + same store => identical replay batches in another
        process (the subprocess leg, mirroring the fingerprint test)."""
        store = self._store(tmp_path)
        local = ReplayBuffer(store, "tpu_v5e",
                             ReplayConfig(per_task=8)).sample()
        code = (
            "import json, numpy as np;"
            "from repro.hub.store import RecordStore;"
            "from repro.continual import ReplayBuffer, ReplayConfig;"
            f"store = RecordStore({str(tmp_path / 's')!r});"
            "r = ReplayBuffer(store, 'tpu_v5e',"
            "                 ReplayConfig(per_task=8)).sample();"
            "print(json.dumps([r.raw_throughput.astype(float).tolist(),"
            "                  r.g.astype(int).tolist()]))")
        env = dict(os.environ)
        src = os.path.join(os.path.dirname(__file__), "..", "src")
        env["PYTHONPATH"] = os.path.abspath(src)
        env.setdefault("JAX_PLATFORMS", "cpu")
        out = subprocess.run([sys.executable, "-c", code], env=env,
                             capture_output=True, text=True, check=True)
        raw, g = json.loads(out.stdout)
        np.testing.assert_array_equal(
            local.raw_throughput, np.asarray(raw, np.float32))
        np.testing.assert_array_equal(local.g, np.asarray(g, np.int32))

    def test_class_balance_caps_lopsided_shards(self, tmp_path):
        store = RecordStore(str(tmp_path / "s"))
        rng = np.random.RandomState(0)
        from repro.autotune.space import random_config
        for i in range(40):                      # fat task A
            store.put("d", WL_A, random_config(WL_A, rng), 10.0 + i)
        for i in range(5):                       # thin task B
            store.put("d", WL_B, random_config(WL_B, rng), 20.0 + i)
        store.flush()
        sample = ReplayBuffer(store, "d", ReplayConfig(per_task=8)).sample()
        counts = np.bincount(sample.g)
        assert counts[0] == 8                    # capped at per_task
        assert counts[1] == 5                    # everything the shard has

    def test_exclude_tail_disjoint_from_fresh(self, tmp_path):
        store = self._store(tmp_path)
        rows = device_rows(store, "tpu_v5e")
        _, tail = split_tail(rows, 4)
        buf = ReplayBuffer(store, "tpu_v5e", ReplayConfig(per_task=64),
                           exclude_tail=4)
        sampled = buf.sample_rows()
        for key, tail_rows in tail.items():
            tail_ids = {json.dumps(r, sort_keys=True) for r in tail_rows}
            got = {json.dumps(r, sort_keys=True)
                   for r in sampled.get(key, [])}
            assert not (tail_ids & got)

    def test_mix_ratio_and_disjoint_groups(self, tmp_path):
        store = self._store(tmp_path, n=32)
        buf = ReplayBuffer(store, "tpu_v5e",
                           ReplayConfig(per_task=32, fresh_ratio=0.5))
        rows = device_rows(store, "tpu_v5e")
        _, tail = split_tail(rows, 8)
        fresh = build_records(tail)
        mix = buf.mix(fresh)
        n_replay = len(mix) - len(fresh)
        # fresh_ratio 0.5 => about one replay row per fresh row
        assert abs(n_replay - len(fresh)) <= 1
        # fresh groups are offset past every replay group
        assert len(np.unique(mix.g)) == 4
        # per-group labels re-normalized over the mixed set
        for g in np.unique(mix.g):
            assert mix.y[mix.g == g].max() == pytest.approx(1.0)

    def test_mix_fresh_ratio_one_disables_replay(self, tmp_path):
        store = self._store(tmp_path)
        buf = ReplayBuffer(store, "tpu_v5e",
                           ReplayConfig(per_task=8, fresh_ratio=1.0))
        fresh = build_records(split_tail(device_rows(store, "tpu_v5e"),
                                         4)[1])
        assert len(buf.mix(fresh)) == len(fresh)


# ---------------------------------------------------------------------------
# regularize
# ---------------------------------------------------------------------------


class TestAnchoredTrain:
    def _records(self, n=32, seed=0):
        rng = np.random.RandomState(seed)
        x = rng.randn(n, 164).astype(np.float32)
        raw = rng.rand(n).astype(np.float32) + 0.1
        g = np.zeros(n, np.int32)
        return Records(x=x, y=raw / raw.max(), g=g, raw_throughput=raw)

    def test_deterministic(self):
        model = resolve_cost_model("mlp", MCFG.cost_model)
        params = model.init(jax.random.PRNGKey(0))
        recs = self._records()
        a, _ = anchored_train(model, params, recs, epochs=2, seed=3)
        b, _ = anchored_train(model, params, recs, epochs=2, seed=3)
        assert param_distance(a, b) == 0.0

    def test_strong_anchor_pins_masked_params(self):
        model = resolve_cost_model("mlp", MCFG.cost_model)
        params = model.init(jax.random.PRNGKey(0))
        recs = self._records()
        w = anchor_weights(model, params, recs, ratio=0.5, strength=1e4)
        free, _ = anchored_train(model, params, recs, anchor=params,
                                 epochs=3, seed=0)
        pinned, _ = anchored_train(model, params, recs, anchor=params,
                                   weights=w, epochs=3, seed=0)
        mask = {k: np.asarray(v) / 1e4 for k, v in w.items()}
        # inside the ticket the huge anchor wins; outside it trains freely
        assert param_distance(pinned, params, mask=mask) < \
            param_distance(free, params, mask=mask) * 0.2
        inv = {k: 1.0 - m for k, m in mask.items()}
        assert param_distance(pinned, params, mask=inv) > 0.0

    def test_anchor_weights_cover_ratio(self):
        model = resolve_cost_model("mlp", MCFG.cost_model)
        params = model.init(jax.random.PRNGKey(1))
        w = anchor_weights(model, params, self._records(), ratio=0.25,
                           strength=2.0)
        tot = sum(np.asarray(v).size for v in w.values())
        on = sum(float((np.asarray(v) > 0).sum()) for v in w.values())
        assert on / tot == pytest.approx(0.25, abs=0.02)
        assert max(float(np.asarray(v).max()) for v in w.values()) == 2.0


# ---------------------------------------------------------------------------
# drift
# ---------------------------------------------------------------------------


class TestDrift:
    def test_fingerprint_no_baseline(self, tmp_path):
        store = RecordStore(str(tmp_path / "s"))
        rep = fingerprint_drift(store, "tpu_v5e")
        assert not rep.drifted and rep.detail == "no saved fingerprint"

    def test_fingerprint_self_is_stable(self, tmp_path):
        store = RecordStore(str(tmp_path / "s"))
        store.put_fingerprint("tpu_v5e", device_fingerprint("tpu_v5e"))
        rep = fingerprint_drift(store, "tpu_v5e")
        assert rep.kind == FINGERPRINT
        assert not rep.drifted and abs(rep.value) < 1e-5

    def test_fingerprint_shift_detected(self, tmp_path):
        store = RecordStore(str(tmp_path / "s"))
        # persisted vector from a very different chip: a drifted device
        store.put_fingerprint("tpu_v5e", device_fingerprint("tpu_edge"))
        rep = fingerprint_drift(store, "tpu_v5e")
        assert rep.drifted and rep.value > 0.02

    def test_calibration_no_params(self, tmp_path):
        model = resolve_cost_model("mlp", MCFG.cost_model)
        rep = calibration_drift(model, None, build_records({}), "d")
        assert not rep.drifted and rep.detail == "no saved params"

    def test_calibration_detects_misranking(self, tmp_path):
        store = RecordStore(str(tmp_path / "s"))
        _boot(store, n=24)
        recs = newest_records(store, "tpu_v5e", 16)
        model = resolve_cost_model("mlp", MCFG.cost_model)
        good, _ = model.train(model.init(jax.random.PRNGKey(0)),
                              store.records("tpu_v5e"), epochs=8)
        rep_good = calibration_drift(model, good, recs, "tpu_v5e",
                                     threshold=0.55)
        # an inverted scorer must read as drifted
        bad = jax.tree.map(lambda a: -a, good)
        rep_bad = calibration_drift(model, bad, recs, "tpu_v5e",
                                    threshold=0.55)
        assert rep_good.value > rep_bad.value
        assert rep_bad.drifted and not rep_good.drifted

    def test_detect_drift_emits_both_kinds(self, tmp_path):
        store = RecordStore(str(tmp_path / "s"))
        _boot(store, n=12)
        model = resolve_cost_model("mlp", MCFG.cost_model)
        reports = detect_drift(store, "tpu_v5e", model=model,
                               params=model.init(jax.random.PRNGKey(0)))
        assert [r.kind for r in reports] == [FINGERPRINT, CALIBRATION]


# ---------------------------------------------------------------------------
# store: versioned params + lineage, compact
# ---------------------------------------------------------------------------


def _params(seed=0):
    rng = np.random.RandomState(seed)
    return {"w0": rng.randn(4, 2).astype(np.float32),
            "b0": np.zeros((2,), np.float32)}


class TestVersionedParams:
    def test_versions_and_parent_chain(self, tmp_path):
        store = RecordStore(str(tmp_path / "s"))
        store.save_model_params("d", _params(0), "mlp")
        store.save_model_params("d", _params(1), "mlp",
                                lineage={"trigger": "drift:fingerprint",
                                         "records_seen": 42})
        lineage = store.model_lineage("d")
        assert [e["version"] for e in lineage] == [1, 2]
        assert lineage[1]["parent"] == 1 and lineage[0]["parent"] is None
        assert lineage[1]["trigger"] == "drift:fingerprint"
        assert lineage[1]["records_seen"] == 42
        assert store.latest_model_version("d") == 2
        np.testing.assert_array_equal(
            np.asarray(store.load_model_params("d", "mlp")["w0"]),
            _params(1)["w0"])

    def test_pinned_version_load(self, tmp_path):
        store = RecordStore(str(tmp_path / "s"))
        store.save_model_params("d", _params(0), "mlp")
        store.save_model_params("d", _params(1), "mlp")
        np.testing.assert_array_equal(
            np.asarray(store.load_model_params("d", "mlp", version=1)["w0"]),
            _params(0)["w0"])

    def test_retire_falls_back_to_parent(self, tmp_path):
        store = RecordStore(str(tmp_path / "s"))
        store.save_model_params("d", _params(0), "mlp")
        store.save_model_params("d", _params(1), "mlp")
        assert store.retire_model("d")            # retires v2
        assert store.latest_model_version("d") == 1
        np.testing.assert_array_equal(
            np.asarray(store.load_model_params("d", "mlp")["w0"]),
            _params(0)["w0"])
        assert store.retire_model("d")            # retires v1 too
        assert store.load_model_params("d", "mlp") is None
        assert not store.retire_model("d")        # nothing left

    def test_family_mismatch_skipped(self, tmp_path):
        store = RecordStore(str(tmp_path / "s"))
        store.save_model_params("d", _params(0), "mlp")
        store.save_model_params("d", _params(1), "residual-mlp")
        # newest matching family wins, not newest overall
        np.testing.assert_array_equal(
            np.asarray(store.load_model_params("d", "mlp")["w0"]),
            _params(0)["w0"])
        assert store.load_model_params("d", "other") is None

    def test_legacy_flat_file_fallback(self, tmp_path):
        store = RecordStore(str(tmp_path / "s"))
        legacy = store._params_path("d")
        os.makedirs(os.path.dirname(legacy), exist_ok=True)
        save_params(legacy, _params(7), meta={"model": "mlp"})
        assert store.latest_model_version("d") == 0
        np.testing.assert_array_equal(
            np.asarray(store.load_model_params("d", "mlp")["w0"]),
            _params(7)["w0"])
        # a versioned save supersedes the legacy file and chains to it
        store.save_model_params("d", _params(8), "mlp")
        lineage = store.model_lineage("d")
        assert [e["version"] for e in lineage] == [0, 1]
        assert lineage[1]["parent"] == 0
        np.testing.assert_array_equal(
            np.asarray(store.load_model_params("d", "mlp")["w0"]),
            _params(8)["w0"])


class TestCompact:
    def _shard(self, root, device="tpu_v5e"):
        return next(
            os.path.join(r, f)
            for r, _, fs in os.walk(os.path.join(root, "records", device))
            for f in fs if f.endswith(".jsonl"))

    def test_drops_duplicates_first_wins(self, tmp_path):
        root = str(tmp_path / "s")
        store = RecordStore(root)
        store.put("tpu_v5e", WL_A, CFG_A, 100.0)
        store.flush()
        shard = self._shard(root)
        with open(shard) as f:
            line = f.readline().strip()
        dup = json.loads(line)
        dup["throughput_gflops"] = 55.0           # same dedup key
        with open(shard, "a") as f:
            f.write(json.dumps(dup) + "\n")
            f.write(line + "\n")
        fresh = RecordStore(root)
        assert fresh.compact() == 2
        recs = list(fresh.iter_device("tpu_v5e"))
        assert len(recs) == 1
        assert recs[0]["throughput_gflops"] == 100.0   # first occurrence

    def test_torn_trailing_line_survives_compact(self, tmp_path):
        """Regression: compacting a shard whose writer was killed mid-append
        must keep every valid record and drop only the torn line."""
        root = str(tmp_path / "s")
        store = RecordStore(root)
        store.put("tpu_v5e", WL_A, CFG_A, 100.0)
        store.put("tpu_v5e", WL_A, CFG_A, 90.0, trial=1)
        store.flush()
        shard = self._shard(root)
        with open(shard, "a") as f:
            f.write('{"schema": 1, "knobs": {"trunc')   # killed writer
        fresh = RecordStore(root)
        assert fresh.compact() == 1                     # the torn line
        assert fresh.count("tpu_v5e") == 2
        # compact is idempotent and reads see the rewritten shard
        assert fresh.compact() == 0
        assert RecordStore(root).count("tpu_v5e") == 2

    def test_compact_flushes_buffered_first(self, tmp_path):
        store = RecordStore(str(tmp_path / "s"))
        store.put("tpu_v5e", WL_A, CFG_A, 100.0)
        assert store.compact() == 0
        assert RecordStore(str(tmp_path / "s")).count("tpu_v5e") == 1


# ---------------------------------------------------------------------------
# lifecycle
# ---------------------------------------------------------------------------


class TestLifecycle:
    def _lc(self, tmp_path, **kw):
        store = RecordStore(str(tmp_path / "s"))
        _boot(store, n=16)
        cfg = kw.pop("cfg", TINY_LC)
        return ModelLifecycle(store, moses_cfg=TINY_CFG, cfg=cfg, **kw)

    def test_initial_refresh_creates_v1(self, tmp_path):
        lc = self._lc(tmp_path)
        assert lc.status("tpu_v5e") == "absent"
        res = lc.refresh("tpu_v5e", force=True)
        assert res.accepted and res.version == 1 and res.parent is None
        assert res.trigger == "initial"
        assert lc.store.model_lineage("tpu_v5e")[-1]["trigger"] == "initial"
        assert lc.serving_params("tpu_v5e") is not None
        assert lc.status("tpu_v5e") == "fresh"

    def test_guard_rejects_regressing_params(self, tmp_path, monkeypatch):
        lc = self._lc(tmp_path)
        assert lc.refresh("tpu_v5e", force=True).accepted
        # force the training step to return garbage: the guard must refuse
        # to ship it and the serving version must not change
        def garbage(device, params, records, **kw):
            return jax.tree.map(lambda a: -a, params), [0.0]
        monkeypatch.setattr(lc.session(), "refresh_params", garbage)
        res = lc.refresh("tpu_v5e", trigger="drift:test")
        assert not res.accepted and "regress" in res.reason
        assert lc.store.latest_model_version("tpu_v5e") == 1
        assert res.holdout_accuracy_new < res.holdout_accuracy_old

    def test_refresh_versions_chain(self, tmp_path):
        lc = self._lc(tmp_path)
        r1 = lc.refresh("tpu_v5e", force=True)
        r2 = lc.refresh("tpu_v5e", trigger="drift:calibration", force=True)
        if r2.accepted:               # guard may legitimately refuse
            assert r2.parent == r1.version
            assert (lc.store.model_lineage("tpu_v5e")[-1]["trigger"]
                    == "drift:calibration")
        else:
            assert lc.store.latest_model_version("tpu_v5e") == r1.version

    def test_min_fresh_floor(self, tmp_path):
        store = RecordStore(str(tmp_path / "s"))
        _boot(store, n=4)
        lc = ModelLifecycle(store, moses_cfg=TINY_CFG,
                            cfg=dataclasses.replace(TINY_LC, min_fresh=64))
        res = lc.refresh("tpu_v5e")
        assert not res.accepted and "min_fresh" in res.reason

    def test_empty_device(self, tmp_path):
        lc = ModelLifecycle(RecordStore(str(tmp_path / "s")),
                            moses_cfg=TINY_CFG, cfg=TINY_LC)
        res = lc.refresh("ghost", force=True)
        assert not res.accepted and res.reason == "no records in store"

    def test_decide_and_maybe_refresh(self, tmp_path):
        lc = self._lc(tmp_path)
        lc.refresh("tpu_v5e", force=True)
        assert lc.decide("tpu_v5e") == "keep"
        assert lc.maybe_refresh("tpu_v5e") is None
        # drifted fingerprint -> refresh
        lc.store.put_fingerprint("tpu_v5e", device_fingerprint("tpu_lite"))
        assert lc.status("tpu_v5e") == "stale"
        decision = lc.decide("tpu_v5e")
        assert decision in ("refresh", "retire")
        if decision == "refresh":
            res = lc.maybe_refresh("tpu_v5e")
            assert res is not None and res.trigger.startswith("drift:")

    def test_retire_grade_drift(self, tmp_path):
        lc = self._lc(tmp_path,
                      cfg=dataclasses.replace(TINY_LC,
                                              retire_threshold=0.0001))
        lc.refresh("tpu_v5e", force=True)
        lc.store.put_fingerprint("tpu_v5e", device_fingerprint("tpu_edge"))
        assert lc.decide("tpu_v5e") == "retire"
        res = lc.maybe_refresh("tpu_v5e")
        assert res is not None and res.reason == "retired"
        assert lc.store.latest_model_version("tpu_v5e") is None
        assert lc.status("tpu_v5e") == "retired"
        # the baseline re-anchored on retire: the same shift must not keep
        # reporting drift (status is retired, not stale, and decide would
        # see no fingerprint drift on a fresh probe)
        rep = fingerprint_drift(lc.store, "tpu_v5e")
        assert not rep.drifted

    def test_retire_abandons_whole_lineage(self, tmp_path):
        """retire() must not fall back to an even older version of the
        same family — the whole chain is invalidated."""
        lc = self._lc(tmp_path)
        lc.refresh("tpu_v5e", force=True)
        lc.refresh("tpu_v5e", force=True)
        # a sibling family's lineage must survive our retire
        lc.store.save_model_params("tpu_v5e", _params(3), "residual-mlp")
        assert lc.retire("tpu_v5e")
        assert lc.serving_params("tpu_v5e") is None
        assert lc.store.latest_model_version("tpu_v5e", "mlp") is None
        assert lc.store.latest_model_version(
            "tpu_v5e", "residual-mlp") is not None

    def test_accepted_drift_refresh_reanchors_fingerprint(self, tmp_path):
        lc = self._lc(tmp_path)
        lc.refresh("tpu_v5e", force=True)
        # a drifted baseline: the persisted vector belongs to another chip
        lc.store.put_fingerprint("tpu_v5e", device_fingerprint("tpu_lite"))
        res = lc.maybe_refresh("tpu_v5e")
        assert res is not None
        if res.accepted:
            # baseline re-anchored to the current probe: drift is resolved
            # and the next check must not re-trigger forever
            assert lc.decide("tpu_v5e") == "keep"
        else:
            # guard refused: baseline must stay drifted (still stale)
            assert lc.decide("tpu_v5e") in ("refresh", "retire")

    def test_drift_summary_shape(self, tmp_path):
        lc = self._lc(tmp_path)
        lc.refresh("tpu_v5e", force=True)
        row = lc.drift_summary("tpu_v5e")
        assert row["status"] == "fresh" and row["version"] == 1
        assert abs(row["fingerprint_shift"]) < 1e-5
        assert {r.kind for r in row["reports"]} == {FINGERPRINT,
                                                    CALIBRATION}


# ---------------------------------------------------------------------------
# hub + launcher integration
# ---------------------------------------------------------------------------


class TestHubIntegration:
    def test_sync_refresh_after_job(self, tmp_path):
        from repro.hub import TuningHub
        # calibration threshold 1.01: every job's device reads as drifted,
        # so the post-job hook must run one (guarded) refresh
        hub = TuningHub(str(tmp_path / "hub"), moses_cfg=TINY_CFG,
                        trials_per_task=16, pretrain_epochs=2,
                        refresh="sync",
                        lifecycle_cfg=dataclasses.replace(
                            TINY_LC, calibration_threshold=1.01))
        _boot(hub.store, devices=("tpu_v5e", "tpu_edge"))
        r = hub.get_config("tpu_v5e_pro", WL_A)
        assert not r.cache_hit
        assert hub.stats.refreshes + hub.stats.refresh_rejects == 1
        if hub.stats.refreshes:
            assert hub.store.latest_model_version("tpu_v5e_pro") is not None

    def test_refresh_off_by_default(self, tmp_path):
        from repro.hub import TuningHub
        hub = TuningHub(str(tmp_path / "hub"), moses_cfg=TINY_CFG,
                        trials_per_task=16, pretrain_epochs=2)
        _boot(hub.store)
        hub.get_config("tpu_v5e_pro", WL_A)
        assert hub.stats.refreshes == 0 and hub.stats.refresh_rejects == 0

    def test_auto_refresh_background(self, tmp_path):
        from repro.hub import TuningHub
        hub = TuningHub(str(tmp_path / "hub"), moses_cfg=TINY_CFG,
                        trials_per_task=16, pretrain_epochs=2,
                        refresh="auto",
                        lifecycle_cfg=dataclasses.replace(
                            TINY_LC, calibration_threshold=1.01))
        _boot(hub.store)
        hub.get_config("tpu_v5e_pro", WL_A)
        hub.join_refreshes()
        assert hub.stats.refreshes + hub.stats.refresh_rejects == 1

    def test_bad_refresh_mode_rejected(self, tmp_path):
        from repro.hub import TuningHub
        with pytest.raises(ValueError):
            TuningHub(str(tmp_path / "hub"), refresh="sometimes")

    def test_accepted_refresh_invalidates_dependent_selections(
            self, tmp_path):
        from repro.hub import TuningHub
        hub = TuningHub(str(tmp_path / "hub"), moses_cfg=TINY_CFG,
                        trials_per_task=16, pretrain_epochs=2,
                        lifecycle_cfg=dataclasses.replace(
                            TINY_LC, calibration_threshold=1.01))
        _boot(hub.store)
        hub.get_config("tpu_v5e_pro", WL_A)
        sel = hub.selection("tpu_v5e_pro")
        assert sel is not None and sel.params_device == "tpu_v5e"
        hub.refresh = "sync"
        hub._run_refresh("tpu_v5e")   # source device gains a version
        if hub.stats.refreshes:
            assert hub.selection("tpu_v5e_pro") is None

    def test_stats_drift_column(self, tmp_path, capsys):
        from repro.hub import TuningHub
        from repro.launch.hub import print_stats
        root = str(tmp_path / "hub")
        hub = TuningHub(root, moses_cfg=TINY_CFG,
                        lifecycle_cfg=TINY_LC)
        _boot(hub.store, n=16)
        hub.store.put_fingerprint("tpu_v5e", device_fingerprint("tpu_v5e"))
        hub.lifecycle.refresh("tpu_v5e", force=True)
        assert print_stats(root, hub=hub) == 0
        out = capsys.readouterr().out
        header = next(ln for ln in out.splitlines() if "fp-shift" in ln)
        assert "rank-acc" in header and "status" in header
        row = next(ln for ln in out.splitlines()
                   if ln.strip().startswith("tpu_v5e "))
        assert "fresh" in row or "stale" in row
        assert "0.0000" in row                     # no fingerprint shift


class TestSessionRefreshParams:
    def test_deterministic_and_isolated(self, tmp_path):
        from repro.autotune.session import TuneSession
        store = RecordStore(str(tmp_path / "s"))
        _boot(store, n=16)
        recs = store.records("tpu_v5e")
        model = resolve_cost_model("mlp", MCFG.cost_model)
        params = model.init(jax.random.PRNGKey(0))
        session = TuneSession(moses_cfg=TINY_CFG, seed=5)
        a, la = session.refresh_params("tpu_v5e", params, recs, epochs=2)
        b, lb = session.refresh_params("tpu_v5e", params, recs, epochs=2)
        assert param_distance(a, b) == 0.0 and la == lb
        # a different device derives a different stream
        c, _ = session.refresh_params("tpu_edge", params, recs, epochs=2)
        assert param_distance(a, c) > 0.0
