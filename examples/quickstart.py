"""Quickstart: the Moses pipeline in one file.

Pre-train a cost model on the source device (tpu_v5p, playing the paper's
K80), transfer it to an embedded-class target (tpu_edge, playing the Jetson
TX2), and compare Moses' lottery-ticket adaptation against the paper's
baselines on a SqueezeNet tuning run.

    PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402

from repro.autotune.dataset import generate_records, training_task_pool  # noqa: E402
from repro.autotune.session import TuneSession  # noqa: E402
from repro.autotune.tasks import paper_dnn_tasks  # noqa: E402
from repro.configs.moses import DEFAULT as MOSES  # noqa: E402
from repro.core.cost_model import rank_correlation, resolve_cost_model  # noqa: E402
from repro.core.metrics import summarize  # noqa: E402


def main():
    # 1. Offline: Tenset-style dataset on the source device + pre-training.
    # The cost model is a registered plugin — swap "mlp" for "residual-mlp"
    # (or your own @register_cost_model class) and the rest is unchanged.
    print("== Step 1: pre-train cost model on source device (tpu_v5p) ==")
    pool = training_task_pool(include_archs=False)
    source = generate_records(pool, MOSES.source_device,
                              programs_per_task=24, seed=0)
    model = resolve_cost_model("mlp", MOSES.cost_model)
    params = model.init(jax.random.PRNGKey(0))
    params, losses = model.train(params, source, epochs=10)
    print(f"   pretrain rank loss {losses[0]:.3f} -> {losses[-1]:.3f}; "
          f"source rank-corr "
          f"{rank_correlation(params, source, model.predict):.3f}")

    # 2. The transfer gap (paper §1: vanilla transfer fails across big gaps)
    far = generate_records(pool[:12], "tpu_edge", programs_per_task=24, seed=5)
    print(f"   rank-corr on tpu_edge WITHOUT adaptation: "
          f"{rank_correlation(params, far, model.predict):.3f}"
          f"  <- the gap Moses closes")

    # 3. Online: tune SqueezeNet on the target under each strategy; the
    # TuneSession shares the pretrained model across jobs and gives each
    # (device, strategy) job an isolated RNG stream
    print("== Step 2: tune SqueezeNet on tpu_edge (paper Fig. 4/5 setting) ==")
    tasks = paper_dnn_tasks("squeezenet")
    session = TuneSession(moses_cfg=MOSES, pretrained_params=params,
                          source_pool=source, seed=1, trials_per_task=32,
                          cost_model=model)
    results = {}
    for strat in ("raw", "tenset-pretrain", "tenset-finetune", "moses"):
        results[strat] = session.run(tasks, "tpu_edge", strat)
        r = results[strat]
        print(f"   {strat:16s} latency={r.model_latency * 1e3:7.3f}ms "
              f"search={r.total_search_seconds:7.1f}s "
              f"measurements={r.total_measurements}")

    # 4. CMAT (paper Table 1)
    print("== Step 3: CMAT vs Tenset-Finetune ==")
    s = summarize(results, "tenset-finetune")
    for k in ("tenset-pretrain", "moses"):
        v = s[k]
        print(f"   {k:16s} latency_gain={v['latency_gain_vs_ref']:.3f} "
              f"search_gain={v['search_gain_vs_ref']:.3f} "
              f"CMAT={v['cmat_vs_ref']:+.1f}%")
    assert s["moses"]["cmat_vs_ref"] > 0, "Moses should win CMAT"
    print("quickstart OK")


if __name__ == "__main__":
    main()
