"""End-to-end training driver: train a language model on the synthetic
Markov stream with the full production stack (sharded train step, AdamW,
checkpointing, straggler watchdog, restart).

Default is a ~10M-parameter danube-family model sized for CPU CI; pass
--model-100m for the ~100M configuration (same code path, longer wall time).

    PYTHONPATH=src python examples/train_lm.py --steps 200
    PYTHONPATH=src python examples/train_lm.py --model-100m --steps 300
"""
import argparse
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402

from repro.configs.base import ModelConfig  # noqa: E402
from repro.models import build_model  # noqa: E402
from repro.train.data import DataConfig, data_iterator  # noqa: E402
from repro.train.optimizer import (AdamW, AdamWConfig,  # noqa: E402
                                   cosine_schedule)
from repro.train.train_loop import LoopConfig, run_training  # noqa: E402


def config_10m() -> ModelConfig:
    return ModelConfig(
        name="lm-10m", num_layers=4, d_model=256, num_heads=4, num_kv_heads=2,
        d_ff=1024, vocab_size=8192, attention_kind="sliding",
        sliding_window=256, scan_layers=False, activation_dtype="float32")


def config_100m() -> ModelConfig:
    return ModelConfig(
        name="lm-100m", num_layers=10, d_model=640, num_heads=10,
        num_kv_heads=5, d_ff=2560, vocab_size=32000,
        attention_kind="sliding", sliding_window=1024, scan_layers=True,
        activation_dtype="float32")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--model-100m", action="store_true")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--checkpoint-dir", default=None)
    args = ap.parse_args()

    cfg = config_100m() if args.model_100m else config_10m()
    model = build_model(cfg)
    n = cfg.param_count()
    print(f"model {cfg.name}: {n / 1e6:.1f}M params")
    mesh = jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    opt = AdamW(AdamWConfig(
        lr=cosine_schedule(args.lr, args.steps // 20 + 1, args.steps),
        weight_decay=0.01))
    data = data_iterator(cfg, DataConfig(batch_size=args.batch,
                                         seq_len=args.seq, seed=0))
    ckpt_dir = args.checkpoint_dir or tempfile.mkdtemp(prefix="repro_lm_")
    loop = LoopConfig(total_steps=args.steps,
                      checkpoint_every=max(args.steps // 4, 1),
                      checkpoint_dir=ckpt_dir, log_every=10)
    state, hist = run_training(model, opt, mesh, data, loop,
                               rng=jax.random.PRNGKey(0))
    first, last = hist[0]["loss"], hist[-1]["loss"]
    print(f"loss {first:.3f} -> {last:.3f} over {len(hist)} steps "
          f"(checkpoints in {ckpt_dir})")
    assert last < first, "training should reduce loss"


if __name__ == "__main__":
    main()
