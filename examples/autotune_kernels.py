"""Cross-device kernel auto-tuning, end to end:

  1. extract the GEMM / attention / scan workloads of an assigned
     architecture (recurrentgemma-2b);
  2. adapt the source-pretrained cost model to the target device with Moses;
  3. persist tuned configs to the registry;
  4. launch the tuned Pallas kernels (interpret mode on CPU) and check them
     against the pure-jnp oracles.

    PYTHONPATH=src python examples/autotune_kernels.py --device tpu_v5e
"""
import argparse
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.autotune.dataset import generate_records, training_task_pool  # noqa: E402
from repro.autotune.registry import Registry  # noqa: E402
from repro.autotune.session import TuneSession  # noqa: E402
from repro.autotune.space import default_config  # noqa: E402
from repro.autotune.tasks import arch_tasks  # noqa: E402
from repro.autotune import devices as dev_mod  # noqa: E402
from repro.configs import get_config  # noqa: E402
from repro.configs.moses import DEFAULT as MOSES  # noqa: E402
from repro.core.cost_model import resolve_cost_model  # noqa: E402
from repro.kernels import ops, ref  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--device", default="tpu_v5e",
                    choices=list(dev_mod.DEVICES))
    ap.add_argument("--arch", default="recurrentgemma-2b")
    ap.add_argument("--trials", type=int, default=32)
    args = ap.parse_args()

    print(f"== extracting workloads from {args.arch} ==")
    tasks = arch_tasks(get_config(args.arch))[:8]
    for t in tasks:
        print(f"   {t.name:20s} {t.kind:10s} dims={t.dims} x{t.count}")

    print("== pre-training + Moses adaptation ==")
    pool = training_task_pool(include_archs=False)
    src = generate_records(pool, MOSES.source_device, programs_per_task=24,
                           seed=0)
    model = resolve_cost_model("mlp", MOSES.cost_model)
    params = model.init(jax.random.PRNGKey(0))
    params, _ = model.train(params, src, epochs=10)
    reg_path = os.path.join(tempfile.mkdtemp(prefix="repro_reg_"),
                            "tuned.json")
    reg = Registry(path=reg_path)
    # session jobs auto-ingest their winners into the registry
    session = TuneSession(moses_cfg=MOSES, pretrained_params=params,
                          source_pool=src, seed=0,
                          trials_per_task=args.trials, registry=reg,
                          cost_model=model)
    result = session.run(tasks, args.device, "moses")
    reg.save()
    ops.set_registry(Registry(path=reg_path))
    print(f"   registry -> {reg_path}")

    print("== tuned vs default (simulated device time) ==")
    for tr in result.tasks:
        t_def = dev_mod.execution_time(tr.workload,
                                       default_config(tr.workload),
                                       dev_mod.DEVICES[args.device],
                                       noisy=False)
        print(f"   {tr.workload.name:20s} tuned={tr.best_latency * 1e6:9.2f}us "
              f"default={t_def * 1e6:9.2f}us "
              f"speedup={t_def / tr.best_latency:5.2f}x "
              f"{dict(tr.best_config.knobs)}")

    print("== launching a tuned Pallas kernel (interpret) vs oracle ==")
    a = jax.random.normal(jax.random.PRNGKey(1), (128, 96))
    b = jax.random.normal(jax.random.PRNGKey(2), (96, 64))
    out = ops.tuned_matmul(a, b, device=args.device, interpret=True)
    want = ref.matmul_ref(a, b)
    err = float(jnp.abs(out.astype(jnp.float32) - want).max())
    scale = float(jnp.abs(want).max())
    # Moses may tune out_bf16=1 (a bandwidth win on the device) -> bf16 tol
    tol = 1e-3 if out.dtype == jnp.float32 else 2e-2
    print(f"   tuned matmul rel err vs oracle: {err / scale:.2e} "
          f"(out dtype {out.dtype})")
    assert err / scale < tol, (err, scale)
    print("autotune_kernels OK")


if __name__ == "__main__":
    main()
