"""Batched serving example: prefill + KV-cache decode through the Engine
(continuous-batching-lite), on the reduced RecurrentGemma config — a hybrid
arch exercising both the local-attention ring cache and the RG-LRU state.

    PYTHONPATH=src python examples/serve_lm.py --requests 6 --max-new 12
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs import get_smoke_config  # noqa: E402
from repro.models import build_model  # noqa: E402
from repro.serve import Engine, Request  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="recurrentgemma-2b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--temperature", type=float, default=0.7)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    model = build_model(cfg)
    mesh = jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.RandomState(0)
    engine = Engine(model, params, mesh,
                    max_len=args.prompt_len + args.max_new + 8,
                    batch_slots=4, seed=0)
    reqs = [Request(prompt=rng.randint(0, cfg.vocab_size,
                                       size=rng.randint(8, args.prompt_len + 1)
                                       ).astype(np.int32),
                    max_new_tokens=args.max_new,
                    temperature=(0.0 if i % 2 == 0 else args.temperature))
            for i in range(args.requests)]
    t0 = time.time()
    engine.generate(reqs)
    dt = time.time() - t0
    n_tok = sum(len(r.out_tokens) for r in reqs)
    print(f"served {len(reqs)} requests ({n_tok} tokens) in {dt:.2f}s -> "
          f"{n_tok / dt:.1f} tok/s on CPU")
    for i, r in enumerate(reqs):
        mode = "greedy" if r.temperature == 0 else f"T={r.temperature}"
        print(f"  req{i} ({mode}, prompt {len(r.prompt)} toks): "
              f"{r.out_tokens}")
    assert all(r.done for r in reqs)
    print("serve_lm OK")


if __name__ == "__main__":
    main()
